"""Mamba2 SSD (state-space duality) chunked scan kernel in Pallas.

Implements the chunked SSD algorithm [Dao & Gu, arXiv:2405.21060] on TPU:
the sequence is split into chunks; within a chunk the output is computed
as a masked attention-like matmul (MXU-friendly), while the recurrent
state (N × P per head) is carried across chunks in VMEM scratch.

Grid: (batch*heads, n_chunks) with chunks innermost so the state scratch
carries. Per the bulk-load principle, all tile reads of a chunk step are
issued before the first matmul.

Validated against the sequential-recurrence oracle
:func:`repro.kernels.ref.ssd_ref` in interpret mode.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def ssd_layout(BH: int, S: int, P: int, N: int, chunk: int) -> dict:
    """The launch geometry of :func:`ssd_scan`, as data.

    Shared by the ``pallas_call`` below and the static grid verifier
    (``repro.verify.grid_check``): per operand a ``(block_shape,
    array_shape, index_map)`` triple over the grid ``(B*H, n_chunks)``.
    Sequence streams tile over chunks; the per-head scalar rows (a_log,
    d_skip) re-read their single block every chunk step."""
    n_chunks = S // chunk

    def seq_map(bh_, ci):
        return (bh_, ci, 0)

    def head_map(bh_, ci):
        return (bh_, 0, 0)

    return {
        "grid": (BH, n_chunks),
        "x": ((1, chunk, P), (BH, S, P), seq_map),
        "dt": ((1, chunk, 128), (BH, S, 128), seq_map),
        "a_log": ((1, 1, 128), (BH, 1, 128), head_map),
        "b": ((1, chunk, N), (BH, S, N), seq_map),
        "c": ((1, chunk, N), (BH, S, N), seq_map),
        "d_skip": ((1, 1, 128), (BH, 1, 128), head_map),
        "o": ((1, chunk, P), (BH, S, P), seq_map),
        "scratch_bytes": N * P * 4,        # the carried (N, P) f32 state
    }


def _ssd_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, d_ref, o_ref, h_scr, *,
                chunk: int):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        h_scr[...] = jnp.zeros_like(h_scr)

    # ---- bulk load: every VMEM read up front --------------------------------
    x = x_ref[0, ...].astype(jnp.float32)        # (L, P)
    dt = dt_ref[0, ...].astype(jnp.float32)      # (L, 128) replicated
    a_log = a_ref[0, ...]                        # (1, 128) replicated
    b = b_ref[0, ...].astype(jnp.float32)        # (L, N)
    c = c_ref[0, ...].astype(jnp.float32)        # (L, N)
    d_skip = d_ref[0, ...]                       # (1, 128) replicated
    h_prev = h_scr[...]                          # (N, P)

    dt1 = dt[:, :1]                              # (L, 1)
    a = -jnp.exp(a_log[0, 0])                    # scalar A for this head
    # cumulative log-decay within the chunk: s_t = sum_{u<=t} dt_u * A
    seg = jnp.cumsum(dt1 * a, axis=0)            # (L, 1), negative
    # intra-chunk: y[t] = sum_{s<=t} C_t·B_s exp(seg_t - seg_s) dt_s x_s
    cb = jax.lax.dot_general(c, b, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)  # (L, L)
    li = lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    lj = lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    decay_mat = jnp.exp(seg - seg.T)             # exp(seg_t - seg_s)
    mask = li >= lj
    scores = jnp.where(mask, cb * decay_mat, 0.0)
    dx = dt1 * x                                 # (L, P)
    y_intra = jax.lax.dot_general(scores, dx, (((1,), (0,)), ((), ())),
                                  preferred_element_type=jnp.float32)
    # inter-chunk: y_t += exp(seg_t) * C_t · h_prev
    ch = jax.lax.dot_general(c, h_prev, (((1,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)  # (L, P)
    y = y_intra + jnp.exp(seg) * ch
    # state update: h = exp(seg_L) h_prev + sum_t exp(seg_L - seg_t) B_t dx_t
    total = seg[-1:, :]                          # (1, 1)
    w = jnp.exp(total - seg)                     # (L, 1)
    bh = jax.lax.dot_general(b * w, dx, (((0,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)  # (N, P)
    h_scr[...] = jnp.exp(total[0, 0]) * h_prev + bh
    o_ref[0, ...] = (y + d_skip[0, 0] * x).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan(x, dt, a_log, b_mat, c_mat, d_skip, *, chunk: int = 128,
             interpret: Optional[bool] = None):
    """Chunked SSD. x:(B,S,H,P) dt:(B,S,H) a_log,d_skip:(H,)
    b_mat,c_mat:(B,S,N) → y:(B,S,H,P)."""
    B, S, H, P = x.shape
    N = b_mat.shape[-1]
    interpret = (jax.default_backend() == "cpu") if interpret is None \
        else interpret
    chunk = min(chunk, S)
    assert S % chunk == 0, f"S={S} not a multiple of chunk={chunk}"
    n_chunks = S // chunk

    # layouts: (B*H, S, ·) per-head streams; replicate per-head scalars to
    # a 128-lane row so the TPU layout is legal.
    xh = jnp.moveaxis(x, 2, 1).reshape(B * H, S, P)
    dth = jnp.moveaxis(dt, 2, 1).reshape(B * H, S, 1)
    dth = jnp.broadcast_to(dth, (B * H, S, 128))
    a_rows = jnp.broadcast_to(
        jnp.tile(a_log.astype(jnp.float32), B)[:, None, None], (B * H, 1, 128))
    d_rows = jnp.broadcast_to(
        jnp.tile(d_skip.astype(jnp.float32), B)[:, None, None], (B * H, 1, 128))
    b_h = jnp.broadcast_to(b_mat[:, None], (B, H, S, N)).reshape(B * H, S, N)
    c_h = jnp.broadcast_to(c_mat[:, None], (B, H, S, N)).reshape(B * H, S, N)

    lay = ssd_layout(B * H, S, P, N, chunk)
    out = pl.pallas_call(
        functools.partial(_ssd_kernel, chunk=chunk),
        grid=lay["grid"],
        in_specs=[pl.BlockSpec(lay[n][0], lay[n][2])
                  for n in ("x", "dt", "a_log", "b", "c", "d_skip")],
        out_specs=pl.BlockSpec(lay["o"][0], lay["o"][2]),
        out_shape=jax.ShapeDtypeStruct(lay["o"][1], x.dtype),
        scratch_shapes=[pltpu.VMEM((N, P), jnp.float32)],
        interpret=interpret,
    )(xh, dth, a_rows, b_h, c_h, d_rows)
    return jnp.moveaxis(out.reshape(B, H, S, P), 1, 2)


def ssd_scan_jnp(x, dt, a_log, b_mat, c_mat, d_skip, *, chunk: int = 128,
                 return_state: bool = False):
    """Chunked SSD in pure jnp (same math, lax.scan over chunks) — the
    fast CPU path for model execution; oracle remains ssd_ref.
    With ``return_state``, also returns the final (B,H,N,P) state (used by
    prefill to seed decode)."""
    B, S, H, P = x.shape
    N = b_mat.shape[-1]
    chunk = min(chunk, S)
    S0 = S
    if S % chunk:
        # pad to a chunk multiple with dt=0 steps (decay=1, no input:
        # state and causal outputs are unchanged), slice back at the end
        pad = chunk - S % chunk
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        b_mat = jnp.pad(b_mat, ((0, 0), (0, pad), (0, 0)))
        c_mat = jnp.pad(c_mat, ((0, 0), (0, pad), (0, 0)))
        S = S + pad
    n_chunks = S // chunk
    a = -jnp.exp(a_log.astype(jnp.float32))                  # (H,)

    xc = x.reshape(B, n_chunks, chunk, H, P).astype(jnp.float32)
    dtc = dt.reshape(B, n_chunks, chunk, H).astype(jnp.float32)
    bc = b_mat.reshape(B, n_chunks, chunk, N).astype(jnp.float32)
    cc = c_mat.reshape(B, n_chunks, chunk, N).astype(jnp.float32)

    def step(h, inp):
        xk, dtk, bk, ck = inp            # (B,L,H,P) (B,L,H) (B,L,N) (B,L,N)
        seg = jnp.cumsum(dtk * a, axis=1)             # (B,L,H)
        cb = jnp.einsum("bln,bmn->blm", ck, bk)       # (B,L,L)
        decay = jnp.exp(seg[:, :, None, :] - seg[:, None, :, :])  # (B,L,L,H)
        mask = jnp.tril(jnp.ones((chunk, chunk), bool))
        scores = jnp.where(mask[None, :, :, None],
                           cb[..., None] * decay, 0.0)  # (B,L,L,H)
        dx = dtk[..., None] * xk                       # (B,L,H,P)
        y_intra = jnp.einsum("blmh,bmhp->blhp", scores, dx)
        chp = jnp.einsum("bln,bhnp->blhp", ck, h)
        y = y_intra + jnp.exp(seg)[..., None] * chp
        total = seg[:, -1:, :]                         # (B,1,H)
        w = jnp.exp(total - seg)                       # (B,L,H)
        bh_ = jnp.einsum("bln,blh,blhp->bhnp", bk, w * dtk, xk)
        h = jnp.exp(total[:, 0, :])[:, :, None, None] * h + bh_
        return h, y

    h0 = jnp.zeros((B, H, N, P), jnp.float32)
    xs = (jnp.moveaxis(xc, 1, 0), jnp.moveaxis(dtc, 1, 0),
          jnp.moveaxis(bc, 1, 0), jnp.moveaxis(cc, 1, 0))
    h_final, ys = lax.scan(step, h0, xs)
    y = jnp.moveaxis(ys, 0, 1).reshape(B, S, H, P)
    out = (y + x.astype(jnp.float32) * d_skip[None, None, :, None]
           ).astype(x.dtype)[:, :S0]
    if return_state:
        return out, h_final
    return out


def ssd_decode_step(h, x_t, dt_t, a_log, b_t, c_t, d_skip):
    """One-token recurrent update for serving. h:(B,H,N,P) x_t:(B,H,P)
    dt_t:(B,H) b_t/c_t:(B,N) → (h', y_t:(B,H,P))."""
    a = -jnp.exp(a_log.astype(jnp.float32))
    decay = jnp.exp(dt_t * a)                          # (B,H)
    dbx = jnp.einsum("bn,bh,bhp->bhnp", b_t, dt_t, x_t)
    h = decay[..., None, None] * h + dbx
    y = jnp.einsum("bn,bhnp->bhp", c_t, h) + d_skip[None, :, None] * x_t
    return h, y
