"""Flash attention for TPU in Pallas — the attention hot-spot kernel.

Online-softmax tiling with VMEM scratch accumulators, causal block
skipping, and GQA-aware KV indexing. Following the paper's bulk-load
principle, both the K and V tiles for a grid step are read from their refs
*before* any compute (the scores matmul), front-loading the HBM→VMEM
traffic of each step.

Grid: (batch*heads, q_blocks, kv_blocks) with kv innermost so the (m, l,
acc) scratch carries across the kv sweep of one q tile.

Validated against :func:`repro.kernels.ref.attention_ref` in interpret
mode (CPU) over shape/dtype sweeps; on TPU the same kernel compiles with
MXU-aligned tiles (q_block × head_dim multiples of (8, 128)).
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def attention_layout(B: int, H: int, KH: int, S: int, D: int,
                     q_block: int, kv_block: int) -> dict:
    """The launch geometry of :func:`flash_attention`, as data.

    One source of truth shared by the ``pallas_call`` below and the
    static grid verifier (``repro.verify.grid_check`` certifies exactly
    these index maps): per operand a ``(block_shape, array_shape,
    index_map)`` triple over the grid ``(B*H, q_steps, kv_steps)``.

    The output map ignores ``ki`` — every kv step of one (bh, qi) pair
    revisits the same output block, finalized on the last step; the
    verifier's inert-axis analysis proves that a legal revisit, not a
    write-write race. K/V indexing is GQA-aware: ``bh // H`` recovers
    the batch, ``(bh % H) // group`` the kv head."""
    assert H % KH == 0, "query heads must be a multiple of kv heads"
    group = H // KH
    q_steps, kv_steps = S // q_block, S // kv_block

    def q_map(bh, qi, ki):
        return (bh, qi, 0)

    def kv_map(bh, qi, ki):
        return (bh // H, (bh % H) // group, ki, 0)

    return {
        "grid": (B * H, q_steps, kv_steps),
        "q": ((1, q_block, D), (B * H, S, D), q_map),
        "k": ((1, 1, kv_block, D), (B, KH, S, D), kv_map),
        "v": ((1, 1, kv_block, D), (B, KH, S, D), kv_map),
        "o": ((1, q_block, D), (B * H, S, D), q_map),
        # m/l accumulators (q_block, 128) + the (q_block, D) f32 acc
        "scratch_bytes": (q_block * 128 * 2 + q_block * D) * 4,
    }


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                 scale: float, causal: bool, q_block: int, kv_block: int,
                 kv_steps: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    def _step():
        # bulk load: all VMEM reads of this step issued before any compute
        q = q_ref[0, ...]                    # (q_block, d)
        k = k_ref[0, 0, ...]                 # (kv_block, d)
        v = v_ref[0, 0, ...]                 # (kv_block, d)
        m_prev = m_scr[...]                  # (q_block, 128) replicated
        l_prev = l_scr[...]
        acc_prev = acc_scr[...]

        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale   # (q_blk, kv_blk)
        if causal:
            q_pos = qi * q_block + lax.broadcasted_iota(
                jnp.int32, (q_block, kv_block), 0)
            k_pos = ki * kv_block + lax.broadcasted_iota(
                jnp.int32, (q_block, kv_block), 1)
            s = jnp.where(q_pos >= k_pos, s, NEG_INF)

        m_cur = jnp.max(s, axis=-1, keepdims=True)          # (q_blk, 1)
        m_new = jnp.maximum(m_prev[:, :1], m_cur)
        alpha = jnp.exp(m_prev[:, :1] - m_new)              # rescale old
        p = jnp.exp(s - m_new)                              # (q_blk, kv_blk)
        l_new = alpha * l_prev[:, :1] + jnp.sum(p, -1, keepdims=True)
        acc = alpha * acc_prev + jax.lax.dot_general(
            p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)

        m_scr[...] = jnp.broadcast_to(m_new, m_scr.shape)
        l_scr[...] = jnp.broadcast_to(l_new, l_scr.shape)
        acc_scr[...] = acc

    if causal:
        # skip fully-masked blocks (query tile entirely above kv tile)
        pl.when((qi + 1) * q_block > ki * kv_block)(_step)
    else:
        _step()

    @pl.when(ki == kv_steps - 1)
    def _finish():
        l = l_scr[...][:, :1]
        l = jnp.where(l == 0.0, 1.0, l)
        o_ref[0, ...] = (acc_scr[...] / l).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "scale", "q_block",
                                             "kv_block", "interpret"))
def flash_attention(q, k, v, *, causal: bool = True,
                    scale: Optional[float] = None,
                    q_block: int = 128, kv_block: int = 128,
                    interpret: Optional[bool] = None):
    """q:(B,H,S,D) k/v:(B,KH,S,D) → (B,H,S,D). GQA when KH < H."""
    B, H, S, D = q.shape
    KH = k.shape[1]
    scale = (D ** -0.5) if scale is None else scale
    interpret = (jax.default_backend() == "cpu") if interpret is None \
        else interpret
    q_block = min(q_block, S)
    kv_block = min(kv_block, S)
    assert S % q_block == 0 and S % kv_block == 0
    kv_steps = S // kv_block

    q3 = q.reshape(B * H, S, D)
    lay = attention_layout(B, H, KH, S, D, q_block, kv_block)

    kernel = functools.partial(
        _attn_kernel, scale=scale, causal=causal, q_block=q_block,
        kv_block=kv_block, kv_steps=kv_steps)

    out = pl.pallas_call(
        kernel,
        grid=lay["grid"],
        in_specs=[pl.BlockSpec(lay[n][0], lay[n][2])
                  for n in ("q", "k", "v")],
        out_specs=pl.BlockSpec(lay["o"][0], lay["o"][2]),
        out_shape=jax.ShapeDtypeStruct(lay["o"][1], q.dtype),
        scratch_shapes=[
            pltpu.VMEM((q_block, 128), jnp.float32),
            pltpu.VMEM((q_block, 128), jnp.float32),
            pltpu.VMEM((q_block, D), jnp.float32),
        ],
        interpret=interpret,
    )(q3, k, v)
    return out.reshape(B, H, S, D)


def decode_attention(q, k_cache, v_cache, *, scale: Optional[float] = None):
    """Single-token decode: q:(B,H,1,D) against k/v:(B,KH,S,D). Pure jnp —
    a GEMV-shaped op; GQA handled by grouped einsums (the repeated-KV
    materialization would dominate decode memory at 32k context)."""
    B, H, Q, D = q.shape
    KH = k_cache.shape[1]
    rep = H // KH
    scale = (D ** -0.5) if scale is None else scale
    qg = q.reshape(B, KH, rep, Q, D)
    logits = jnp.einsum("bkgqd,bksd->bkgqs", qg, k_cache,
                        preferred_element_type=jnp.float32) * scale
    probs = jax.nn.softmax(logits, axis=-1)
    o = jnp.einsum("bkgqs,bksd->bkgqd", probs.astype(q.dtype), v_cache)
    return o.reshape(B, H, Q, D)
