"""Pure-jnp oracles for every kernel in this package.

These are the ground truth for tests (``assert_allclose`` against both the
saturated JAX codegen and the Pallas kernels in interpret mode) and the
CPU fallback path for model execution.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax


def rmsnorm_ref(x, g, eps=1e-6):
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return x * lax.rsqrt(var + eps) * g


def rmsnorm_gated_ref(x, z, g, eps=1e-6):
    xg = x * (z * lax.logistic(z))
    var = jnp.mean(jnp.square(xg), axis=-1, keepdims=True)
    return xg * lax.rsqrt(var + eps) * g


def layernorm_ref(x, g, b, eps=1e-6):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    xc = x - mu
    var = jnp.mean(jnp.square(xc), axis=-1, keepdims=True)
    return xc * lax.rsqrt(var + eps) * g + b


def swiglu_ref(a, b):
    return a * lax.logistic(a) * b


def gelu_ref(a):
    return 0.5 * a * (1.0 + jnp.tanh(
        0.7978845608028654 * (a + 0.044715 * a ** 3)))


def rotate_half_ref(x):
    h = x.shape[-1] // 2
    return jnp.concatenate([-x[..., h:], x[..., :h]], axis=-1)


def rotary_ref(q, cos, sin):
    return q * cos + rotate_half_ref(q) * sin


def residual_scale_ref(x, y, alpha=1.0):
    return x + alpha * y


def softmax_ref(x):
    e = jnp.exp(x - jnp.max(x, axis=-1, keepdims=True))
    return e / jnp.sum(e, axis=-1, keepdims=True)


def adamw_ref(param, grad, m, v, *, lr, b1, b2, eps, wd, inv_bc1, inv_bc2):
    m_new = b1 * m + (1.0 - b1) * grad
    v_new = b2 * v + (1.0 - b2) * grad * grad
    mhat = m_new * inv_bc1
    vhat = v_new * inv_bc2
    update = mhat / (jnp.sqrt(vhat) + eps) + wd * param
    return m_new, v_new, param - lr * update


def sgd_momentum_ref(param, grad, m, *, lr, mu):
    m_new = mu * m + grad
    return m_new, param - lr * m_new


def ssd_gate_ref(dt_raw, a_log, *, bias=0.0):
    dt = jax.nn.softplus(dt_raw + bias)
    decay = jnp.exp(dt * (-jnp.exp(a_log)))
    return dt, decay


def l2_clip_ref(g, *, norm, max_norm, eps=1e-9):
    scale = jnp.minimum(1.0, max_norm / (norm + eps))
    return g * scale


def attention_ref(q, k, v, *, causal=True, scale=None):
    """Naive attention oracle. q:(B,H,S,D) k/v:(B,KH,S,D); GQA by repeat."""
    B, H, S, D = q.shape
    KH = k.shape[1]
    if KH != H:
        rep = H // KH
        k = jnp.repeat(k, rep, axis=1)
        v = jnp.repeat(v, rep, axis=1)
    scale = (D ** -0.5) if scale is None else scale
    logits = jnp.einsum("bhqd,bhkd->bhqk", q, k) * scale
    if causal:
        mask = jnp.tril(jnp.ones((S, S), bool))
        logits = jnp.where(mask, logits, -1e30)
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", probs.astype(q.dtype), v)


def ssd_ref(x, dt, a_log, b_mat, c_mat, d_skip):
    """Mamba2 SSD oracle: sequential recurrence via lax.scan.

    x:(B,S,H,P) dt:(B,S,H) a_log:(H,) b_mat/c_mat:(B,S,N) d_skip:(H,)
    h_t = exp(dt*A)·h_{t-1} + dt·(B_t ⊗ x_t);  y_t = C_t·h_t + D·x_t
    """
    Bsz, S, H, P = x.shape
    N = b_mat.shape[-1]
    A = -jnp.exp(a_log)  # (H,)

    def step(h, inputs):
        xt, dtt, bt, ct = inputs         # (B,H,P) (B,H) (B,N) (B,N)
        decay = jnp.exp(dtt * A)         # (B,H)
        dbx = jnp.einsum("bn,bh,bhp->bhnp", bt, dtt, xt)
        h = decay[..., None, None] * h + dbx
        y = jnp.einsum("bn,bhnp->bhp", ct, h)
        return h, y

    h0 = jnp.zeros((Bsz, H, N, P), jnp.float32)
    xs = (jnp.moveaxis(x, 1, 0).astype(jnp.float32),
          jnp.moveaxis(dt, 1, 0).astype(jnp.float32),
          jnp.moveaxis(b_mat, 1, 0).astype(jnp.float32),
          jnp.moveaxis(c_mat, 1, 0).astype(jnp.float32))
    _, ys = lax.scan(step, h0, xs)
    y = jnp.moveaxis(ys, 0, 1)           # (B,S,H,P)
    return (y + x.astype(jnp.float32) * d_skip[None, None, :, None]
            ).astype(x.dtype)
