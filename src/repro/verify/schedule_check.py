"""Schedule-legality pass: independent certification of emitted orders.

:mod:`repro.core.schedule` builds a dependence DAG and asserts its own
orders are topological — but a bug in its edge construction would
certify its own output. This pass is the N-version check: it re-derives
the dependence requirements of every scheduled unit **from the SSA
structure and the extracted choice alone** (never reading
``SchedUnit.deps``) and replays the emitted order as a forward
simulation:

* **RAW (data)** — a unit may only issue once every unit in the chosen
  cone of its operands has issued, and a load of an array version only
  after the store/loop defining that version;
* **WAR (anti)** — a store/loop overwriting a version must wait for
  every reader (load, or loop carrying the version in) of the
  overwritten version — the Pallas emitter rebinds refs in place, so
  this is a real hazard;
* **store-store** — stores to one array issue in version-chain order;
* **coverage** — the order is a permutation of the region's units and
  every store/loop of the SSA region appears exactly once.

Any emitted order — ``source``/``bulk``/``cost`` or a cached replay
(``fixed_orders``) — can be certified; a clean pass means the order is
a legal topological order of the independently derived dependences.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Set, Tuple

from repro.core.ssa import LoopRegion, Region, SSAResult, StoreEffect

from .findings import PASS_SCHEDULE, Finding


@dataclasses.dataclass
class ScheduleCheckResult:
    findings: List[Finding] = dataclasses.field(default_factory=list)
    regions_checked: int = 0
    regions_certified: int = 0   # regions with zero error findings

    @property
    def ok(self) -> bool:
        return not any(f.severity == "error" for f in self.findings)


def _loop_roots(loop: LoopRegion) -> List[int]:
    """Every e-class a loop's emission demands (independent walk of the
    SSA structure — bounds, carry init/next, body store operands)."""
    out = [loop.start_cid, loop.stop_cid]
    for c in loop.carries:
        out.extend([c.init_cid, c.next_cid])

    def body(region: Region):
        for it in region.items:
            if isinstance(it, StoreEffect):
                out.append(it.value_cid)
                out.extend(it.index_cids)
                if it.pred_cid is not None:
                    out.append(it.pred_cid)
            else:
                out.extend(_loop_roots(it))
    body(loop.body)
    return out


def _unit_desc(u) -> str:
    if u.kind in ("load", "compute"):
        return f"{u.kind}(cid={u.cid})"
    if u.kind == "store":
        return f"store({u.item.array}→{u.item.version_out})"
    return f"loop(id={u.item.loop_id})"


def verify_schedule(ssa: SSAResult, choice, sched) -> ScheduleCheckResult:
    """Certify every region order of ``sched`` against independently
    re-derived RAW/WAR/store-store dependences."""
    eg = ssa.egraph
    choice = dict(choice)

    def node(cid: int):
        cid = eg.find(cid)
        nd = choice.get(cid)
        if nd is None:
            # classes demanded after extraction (late preds/indices) get
            # the same greedy local completion codegen uses
            from repro.core.extract import extract_dag
            res = extract_dag(eg, (cid,), local_search=False)
            for k, v in res.choice.items():
                choice.setdefault(k, v)
            nd = choice[cid]
        return nd

    items_by_path: Dict[Tuple[int, ...], list] = {}

    def index(region: Region, path: Tuple[int, ...]):
        items_by_path[path] = list(region.items)
        for it in region.items:
            if isinstance(it, LoopRegion):
                index(it.body, path + (it.loop_id,))
    index(ssa.region, ())

    res = ScheduleCheckResult()
    for path in items_by_path:
        if path not in sched.regions:
            res.findings.append(Finding(
                PASS_SCHEDULE, "error", "missing-region",
                f"SSA region {path!r} has no schedule",
                subject=f"region{path}"))

    for path, rs in sorted(sched.regions.items()):
        res.regions_checked += 1
        before = len([f for f in res.findings if f.severity == "error"])
        _check_region(eg, node, path, items_by_path.get(path, []),
                      rs, res.findings)
        after = len([f for f in res.findings if f.severity == "error"])
        if after == before:
            res.regions_certified += 1
    return res


def _check_region(eg, node, path, items, rs, findings: List[Finding]):
    units = rs.units
    order = rs.order
    region_tag = f"region{path}"

    uids = sorted(u.uid for u in units)
    if sorted(order) != uids:
        findings.append(Finding(
            PASS_SCHEDULE, "error", "not-a-permutation",
            f"order {order} is not a permutation of unit ids {uids}",
            subject=region_tag))
        return

    # -- coverage: every SSA store/loop of this region, exactly once ------
    # keyed structurally (store version chain / loop id are unique), so
    # replayed or deserialized schedules with equal-but-distinct item
    # objects still certify
    def item_key(it):
        if isinstance(it, StoreEffect):
            return ("store", it.array, it.version_out)
        return ("loop", it.loop_id)

    unit_keys = [item_key(u.item) for u in units
                 if u.kind in ("store", "loop")]
    expected = [item_key(it) for it in items]
    for key in expected:
        hits = unit_keys.count(key)
        if hits != 1:
            findings.append(Finding(
                PASS_SCHEDULE, "error", "region-incomplete",
                f"SSA {key[0]} {key[1:]} appears {hits}× in the "
                f"schedule (expected once)", subject=region_tag))
    for key in unit_keys:
        if key not in expected:
            findings.append(Finding(
                PASS_SCHEDULE, "error", "foreign-item",
                f"schedule contains {key[0]} {key[1:]} not in this SSA "
                f"region", subject=region_tag))

    # -- independent requirement derivation -------------------------------
    cid_unit: Dict[int, int] = {eg.find(u.cid): u.uid for u in units
                                if u.cid is not None}
    loop_uid: Dict[int, int] = {u.item.loop_id: u.uid for u in units
                                if u.kind == "loop"}
    sym_def: Dict[str, int] = {}
    for u in units:
        if u.kind == "store":
            sym_def[u.item.version_out] = u.uid
        elif u.kind == "loop":
            for ac in u.item.array_carries:
                sym_def[ac.version_body] = u.uid
                sym_def[ac.version_post] = u.uid

    def cone(self_uid: int, roots) -> Tuple[Set[int], Set[str]]:
        req: Set[int] = set()
        syms: Set[str] = set()
        seen: Set[int] = set()

        def walk(cid: int):
            cid = eg.find(cid)
            if cid in seen:
                return
            seen.add(cid)
            owner = cid_unit.get(cid)
            if owner is not None and owner != self_uid:
                req.add(owner)
                return
            nd = node(cid)
            if nd.op == "array":
                syms.add(nd.payload)
                return
            if nd.op == "phi_loop":
                lu = loop_uid.get(nd.payload[0])
                if lu is not None and lu != self_uid:
                    req.add(lu)
                walk(nd.children[0])  # init value
                return
            for ch in nd.children:
                walk(ch)

        for r in roots:
            walk(r)
        return req, syms

    requires: Dict[int, Set[int]] = {}
    readers: Dict[str, List[int]] = {}
    overwrites: Dict[int, List[str]] = {}
    for u in units:
        if u.kind in ("load", "compute"):
            req, syms = cone(u.uid, node(u.cid).children)
        elif u.kind == "store":
            it = u.item
            roots = [it.value_cid] + list(it.index_cids)
            if it.pred_cid is not None:
                roots.append(it.pred_cid)
            req, syms = cone(u.uid, roots)
            syms.add(it.version_in)          # store chain (RAW)
            overwrites[u.uid] = [it.version_in]
        else:                                 # loop
            req, syms = cone(u.uid, _loop_roots(u.item))
            for ac in u.item.array_carries:
                syms.add(ac.version_init)    # carried array enters here
            overwrites[u.uid] = [ac.version_init
                                 for ac in u.item.array_carries]
        for sym in syms:
            d = sym_def.get(sym)
            if d is not None and d != u.uid:
                req.add(d)
            readers.setdefault(sym, []).append(u.uid)
        requires[u.uid] = req

    # WAR: whoever overwrites a version waits for all its readers
    for uid, syms in overwrites.items():
        for sym in syms:
            for rd in readers.get(sym, []):
                if rd != uid:
                    requires[uid].add(rd)

    # -- replay the emitted order -----------------------------------------
    pos = {uid: i for i, uid in enumerate(order)}
    by_uid = {u.uid: u for u in units}
    for u in units:
        late = sorted(d for d in requires[u.uid] if pos[d] >= pos[u.uid])
        if late:
            deps_txt = ", ".join(
                f"{_unit_desc(by_uid[d])}@{pos[d]}" for d in late)
            findings.append(Finding(
                PASS_SCHEDULE, "error", "illegal-order",
                f"{_unit_desc(u)} at slot {pos[u.uid]} issues before "
                f"its dependences: {deps_txt}",
                subject=f"{region_tag}:{_unit_desc(u)}"))


# -- pipelined emission plans (PR 8) ------------------------------------------
def verify_async_plan(ssa: SSAResult, sched, plan) -> List[Finding]:
    """Certify a pipelined Pallas emission plan against its schedule.

    ``plan`` is the :class:`repro.core.pallasgen.AsyncCopy` sequence the
    pipelined emitter recorded. Checks, per copy: the start sits at its
    load's scheduled slot, the wait strictly follows the start and
    dominates the load's first consumer, semaphore parity alternates
    with copy index, and no semaphore carries two copies in flight (the
    double-buffer invariant). Straight-line tile programs only — the
    plan lives entirely in the root region."""
    eg = ssa.egraph
    out: List[Finding] = []
    region = sched.regions.get(())
    if region is None:
        if plan:
            out.append(Finding(
                PASS_SCHEDULE, "error", "async-plan-region",
                f"{len(plan)} async copies recorded but the schedule "
                f"has no root region", subject="async-plan"))
        return out
    units = list(region.ordered_units())
    load_slot: Dict[int, int] = {}
    load_uid: Dict[int, int] = {}
    for i, u in enumerate(units):
        if u.kind == "load" and u.cid is not None:
            load_slot[eg.find(u.cid)] = i
            load_uid[eg.find(u.cid)] = u.uid
    first_consumer: Dict[int, int] = {}
    for i, u in enumerate(units):
        for d in u.deps:
            first_consumer.setdefault(d, i)
    for cp in plan:
        subj = f"async-plan:_cp{cp.index}"
        if cp.sem != cp.index % 2:
            out.append(Finding(
                PASS_SCHEDULE, "error", "async-buffer-parity",
                f"copy {cp.index} ({cp.array}) uses semaphore "
                f"{cp.sem}; double buffering requires {cp.index % 2}",
                subject=subj))
        cid = eg.find(cp.cid)
        slot = load_slot.get(cid)
        if slot is None:
            out.append(Finding(
                PASS_SCHEDULE, "error", "async-start-slot",
                f"copy {cp.index} ({cp.array}) has no matching load "
                f"unit in the schedule", subject=subj))
            continue
        if cp.start_slot != slot:
            out.append(Finding(
                PASS_SCHEDULE, "error", "async-start-slot",
                f"copy {cp.index} ({cp.array}) starts at slot "
                f"{cp.start_slot}, but its load is scheduled at "
                f"{slot}", subject=subj))
        if cp.wait_slot < 0:
            out.append(Finding(
                PASS_SCHEDULE, "error", "unmatched-async-start",
                f"copy {cp.index} ({cp.array}) was never waited",
                subject=subj))
            continue
        if cp.wait_slot <= cp.start_slot:
            out.append(Finding(
                PASS_SCHEDULE, "error", "async-wait-order",
                f"copy {cp.index} ({cp.array}) waits at slot "
                f"{cp.wait_slot}, not after its start at "
                f"{cp.start_slot}", subject=subj))
        fc = first_consumer.get(load_uid[cid])
        if fc is not None and cp.wait_slot > fc:
            out.append(Finding(
                PASS_SCHEDULE, "error", "async-wait-order",
                f"copy {cp.index} ({cp.array}) waits at slot "
                f"{cp.wait_slot}, after its first consumer at slot "
                f"{fc} — the wait must dominate the first use",
                subject=subj))
    by_index = sorted(plan, key=lambda c: c.index)
    for i, c1 in enumerate(by_index):
        for c2 in by_index[i + 1:]:
            if c1.sem != c2.sem or c1.wait_slot < 0:
                continue
            if c2.start_slot < c1.wait_slot:
                out.append(Finding(
                    PASS_SCHEDULE, "error", "async-sem-overlap",
                    f"copies {c1.index} and {c2.index} are both in "
                    f"flight on semaphore {c1.sem} (start "
                    f"{c2.start_slot} before wait {c1.wait_slot})",
                    subject=f"async-plan:sem{c1.sem}"))
    return out
