"""E-graph invariant checking (pass 2).

Validates the representation invariants the egg design relies on —
after ``run_rules`` and after a cache ``graft_choice``, both of which
end in ``rebuild()``:

* **union-find** — parent pointers are in range and converge (no
  cycles), and every key of ``classes`` is its own canonical root;
* **hashcons / congruence closure** — every hash-consed node's
  canonical form is present and maps into the same class, every node
  stored in a class hash-conses back into that class, and no two
  distinct classes contain the same canonical node (two congruent
  nodes in different classes = congruence closure broken);
* **analysis consistency** — a class whose constant analysis folded
  must actually contain that constant node, an ``array`` symbol class
  must carry the declared :class:`~repro.analysis.opstats.ArrayInfo`
  (dtype mismatch is an error; shape disagreement after a merge is a
  warning, since merges keep the root's description by design), and a
  ``load`` class's ainfo must dtype-agree with what query-time
  inference derives.

Exposed as :meth:`repro.core.egraph.EGraph.check_invariants`.
"""
from __future__ import annotations

from typing import Dict, List

from .findings import PASS_EGRAPH, Finding


def check_egraph(eg) -> List[Finding]:
    """All invariant violations of ``eg`` (empty list = consistent)."""
    out: List[Finding] = []
    n = len(eg.uf.parent)

    # -- union-find structure ----------------------------------------------
    for x in range(n):
        node, steps = x, 0
        while eg.uf.parent[node] != node:
            p = eg.uf.parent[node]
            if not (0 <= p < n):
                out.append(Finding(
                    PASS_EGRAPH, "error", "uf-out-of-range",
                    f"parent[{node}] = {p} outside [0, {n})",
                    subject=str(x)))
                return out
            node, steps = p, steps + 1
            if steps > n:
                out.append(Finding(
                    PASS_EGRAPH, "error", "uf-cycle",
                    f"parent chain from {x} does not converge",
                    subject=str(x)))
                return out

    if eg.pending:
        out.append(Finding(
            PASS_EGRAPH, "info", "rebuild-pending",
            f"{len(eg.pending)} merges await rebuild(); congruence "
            f"checks reflect the pre-rebuild state"))

    for cid in eg.classes:
        if eg.find(cid) != cid:
            out.append(Finding(
                PASS_EGRAPH, "error", "non-canonical-class",
                f"classes[{cid}] is not its own root "
                f"(find → {eg.find(cid)})", subject=str(cid)))

    # -- hashcons ----------------------------------------------------------
    for node, cid in eg.hashcons.items():
        if not (0 <= cid < n) or any(not (0 <= ch < n)
                                     for ch in node.children):
            out.append(Finding(
                PASS_EGRAPH, "error", "hashcons-out-of-range",
                f"{node!r} → {cid} references ids outside [0, {n})",
                subject=repr(node)))
            continue
        canon = eg.canonicalize(node)
        mapped = eg.hashcons.get(canon)
        if mapped is None:
            out.append(Finding(
                PASS_EGRAPH, "error", "hashcons-stale",
                f"canonical form {canon!r} of hash-consed {node!r} is "
                f"not hash-consed", subject=repr(node)))
        elif eg.find(mapped) != eg.find(cid):
            out.append(Finding(
                PASS_EGRAPH, "error", "hashcons-inconsistent",
                f"{node!r} → class {eg.find(cid)} but its canonical "
                f"form → class {eg.find(mapped)}", subject=repr(node)))

    # -- class membership + congruence closure -----------------------------
    canon_owner: Dict[object, int] = {}
    for cid, ec in eg.eclasses().items():
        for node in ec.nodes:
            if any(not (0 <= ch < n) for ch in node.children):
                out.append(Finding(
                    PASS_EGRAPH, "error", "node-out-of-range",
                    f"{node!r} in class {cid} has out-of-range children",
                    subject=str(cid)))
                continue
            canon = eg.canonicalize(node)
            h = eg.hashcons.get(canon)
            if h is None:
                out.append(Finding(
                    PASS_EGRAPH, "error", "unhashconsed-member",
                    f"{canon!r} is in class {cid} but not hash-consed",
                    subject=str(cid)))
            elif not (0 <= h < n):
                pass  # already reported as hashcons-out-of-range above
            elif eg.find(h) != cid:
                out.append(Finding(
                    PASS_EGRAPH, "error", "member-maps-elsewhere",
                    f"{canon!r} sits in class {cid} but hash-conses to "
                    f"class {eg.find(h)}", subject=str(cid)))
            owner = canon_owner.get(canon)
            if owner is not None and owner != cid:
                out.append(Finding(
                    PASS_EGRAPH, "error", "congruence-violation",
                    f"congruent node {canon!r} appears in distinct "
                    f"classes {owner} and {cid}", subject=repr(canon)))
            canon_owner[canon] = cid

        # -- constant-folding analysis ------------------------------------
        if eg.enable_const_fold and ec.data is not None:
            if not any(m.op == "const" and m.payload == ec.data
                       and type(m.payload) is type(ec.data)
                       for m in ec.nodes):
                out.append(Finding(
                    PASS_EGRAPH, "error", "data-without-const",
                    f"class {cid} folded to {ec.data!r} but contains no "
                    f"matching const node", subject=str(cid)))

        # -- array-operand (ainfo) analysis -------------------------------
        for node in ec.nodes:
            if node.op == "array":
                declared = eg.array_info.get(eg._array_base(node.payload))
                if declared is None:
                    continue
                if ec.ainfo is None:
                    out.append(Finding(
                        PASS_EGRAPH, "error", "ainfo-missing",
                        f"array class {cid} ({node.payload}) lost its "
                        f"declared operand info", subject=str(node.payload)))
                elif ec.ainfo.dtype != declared.dtype:
                    out.append(Finding(
                        PASS_EGRAPH, "error", "ainfo-dtype-mismatch",
                        f"array class {cid} ({node.payload}) carries "
                        f"dtype {ec.ainfo.dtype} vs declared "
                        f"{declared.dtype}", subject=str(node.payload)))
                elif ec.ainfo.shape != declared.shape:
                    out.append(Finding(
                        PASS_EGRAPH, "warning", "ainfo-shape-mismatch",
                        f"array class {cid} ({node.payload}) carries "
                        f"shape {ec.ainfo.shape} vs declared "
                        f"{declared.shape} (merge kept the root's "
                        f"description)", subject=str(node.payload)))
            elif node.op == "load" and ec.ainfo is not None:
                inferred = eg.load_operand_info(eg.canonicalize(node))
                if inferred is not None and \
                        inferred.dtype != ec.ainfo.dtype:
                    out.append(Finding(
                        PASS_EGRAPH, "warning", "load-ainfo-drift",
                        f"load class {cid} carries dtype "
                        f"{ec.ainfo.dtype} but query-time inference "
                        f"gives {inferred.dtype}", subject=str(cid)))
    return out
