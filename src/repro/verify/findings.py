"""Severity-tagged findings shared by every verification pass (PR 7).

A :class:`Finding` is one observed violation (or note) from a static
pass; a :class:`VerifyReport` aggregates the findings of a whole
verification run plus the coverage counters the telemetry layer and
``benchmarks/verify_sweep.py`` surface (``rules_checked``,
``schedules_certified``, ...).

Severities:

* ``"error"``   — a soundness/legality violation: an unsound rule, a
  broken e-graph invariant, a non-topological statement order, an
  out-of-bounds index. CI gates on zero of these.
* ``"warning"`` — suspicious but not provably wrong (dead loads,
  write-write ref races, dtype disagreement across a merge).
* ``"info"``    — advisory: documented ``finite_math`` rule gating,
  memory-access-order (overlap-distance) lint notes.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Iterable, List

SEVERITIES = ("error", "warning", "info")

# Pass names — the keys of ``findings_by_pass`` everywhere.
PASS_RULES = "rules"
PASS_EGRAPH = "egraph"
PASS_SCHEDULE = "schedule"
PASS_CODEGEN = "codegen"
PASS_GRID = "grid"


@dataclasses.dataclass(frozen=True)
class Finding:
    """One verification finding.

    ``code`` is a stable kebab-case identifier tests and CI match on
    (e.g. ``"unsound-rule"``, ``"illegal-order"``, ``"oob-index"``);
    ``subject`` names the checked object (rule name, e-class, unit,
    array)."""
    pass_name: str
    severity: str
    code: str
    message: str
    subject: str = ""

    def __post_init__(self):
        if self.severity not in SEVERITIES:
            raise ValueError(f"severity must be one of {SEVERITIES}, "
                             f"got {self.severity!r}")

    def __str__(self) -> str:
        subj = f" [{self.subject}]" if self.subject else ""
        return f"{self.severity}:{self.pass_name}:{self.code}{subj} " \
               f"{self.message}"


@dataclasses.dataclass
class VerifyReport:
    """Findings + coverage counters of one verification run."""
    findings: List[Finding] = dataclasses.field(default_factory=list)
    rules_checked: int = 0
    schedules_certified: int = 0
    egraphs_checked: int = 0
    sources_checked: int = 0
    grids_checked: int = 0

    def add(self, f: Finding) -> None:
        self.findings.append(f)

    def extend(self, fs: Iterable[Finding]) -> None:
        self.findings.extend(fs)

    def merge(self, other: "VerifyReport") -> None:
        self.findings.extend(other.findings)
        self.rules_checked += other.rules_checked
        self.schedules_certified += other.schedules_certified
        self.egraphs_checked += other.egraphs_checked
        self.sources_checked += other.sources_checked
        self.grids_checked += other.grids_checked

    def errors(self) -> List[Finding]:
        return [f for f in self.findings if f.severity == "error"]

    def by_severity(self) -> Dict[str, int]:
        out = {s: 0 for s in SEVERITIES}
        for f in self.findings:
            out[f.severity] += 1
        return out

    def by_pass(self) -> Dict[str, int]:
        out: Dict[str, int] = {}
        for f in self.findings:
            out[f.pass_name] = out.get(f.pass_name, 0) + 1
        return out

    @property
    def ok(self) -> bool:
        """True when no error-severity finding was recorded."""
        return not self.errors()

    def summary(self) -> Dict[str, Any]:
        """JSON-able digest (what benchmarks/telemetry persist)."""
        return {
            "ok": self.ok,
            "findings": len(self.findings),
            "by_severity": self.by_severity(),
            "by_pass": self.by_pass(),
            "rules_checked": self.rules_checked,
            "schedules_certified": self.schedules_certified,
            "egraphs_checked": self.egraphs_checked,
            "sources_checked": self.sources_checked,
            "grids_checked": self.grids_checked,
        }
