"""Rule-soundness pass: structural lint + differential validation.

Every :class:`repro.core.rules.Rule` is a (lhs, rhs) pattern pair the
saturator treats as a semantics-preserving equality. This pass checks
that claim from two sides:

* **structural lint** — every RHS pattern variable is bound on the LHS
  (an unbound variable would instantiate from a missing substitution),
  every operator exists in the IR vocabulary with the right arity, and
  each rule is classified by size growth (expanding rules are what blow
  e-graphs up; the classification is reported, not judged);
* **differential validation** — LHS and RHS are evaluated under the
  shared :data:`repro.core.ir.EVAL_FNS` semantics over (a) a random
  tier of well-conditioned float64 environments, (b) a bf16 tier of
  values quantized to the bfloat16 grid, and (c) an adversarial tier
  sweeping ±0.0, ±inf, NaN, double denormals and near-overflow
  magnitudes. A random/bf16-tier disagreement is always an
  ``error`` (the rule is wrong on ordinary finite math); an
  adversarial-tier disagreement is an ``error`` unless the rule is
  explicitly gated with ``finite_math=True`` (then it is a documented
  ``info`` note — the rule assumes no overflow/non-finite operands,
  e.g. reassociation or div→reciprocal strength reduction).

Comparison tolerates rounding re-association (|x−y| ≤ 1e-9 + 1e-9·max)
and treats NaN==NaN; genuinely unsound rules (e.g. add→sub) differ at
O(1) and are always caught. All environments are deterministic (seeded)
so findings are reproducible across runs and machines.
"""
from __future__ import annotations

import dataclasses
import itertools
import math
import random
from typing import Any, Dict, Iterable, List, Optional, Set, Tuple

from repro.core.egraph import PatVar, Pattern
from repro.core.ir import (ALL_OPS, BINOPS, CMPOPS, EVAL_FNS, REDOPS,
                           STRUCTOPS, TERNOPS, UNOPS)

from .findings import PASS_RULES, Finding

# Fixed-arity operator table for the structural lint. Structural /
# memory ops (load, call, phi_loop, ...) are variadic or carry payload
# semantics rules should not rewrite — their use in a pattern is
# flagged as a warning below.
_ARITY: Dict[str, int] = {}
for _op in BINOPS + CMPOPS:
    _ARITY[_op] = 2
for _op in UNOPS + REDOPS + STRUCTOPS:
    _ARITY[_op] = 1
for _op in TERNOPS:
    _ARITY[_op] = 3
_ARITY["phi"] = 3

_RTOL = 1e-9
_ATOL = 1e-9

# Adversarial operand values: signed zeros, non-finite, double
# denormals (recip overflows), near-overflow magnitudes (reassociation
# overflows) and a couple of ordinary anchors.
_SPECIALS: Tuple[float, ...] = (
    0.0, -0.0, 1.0, -1.0, 0.5, 2.0,
    float("inf"), float("-inf"), float("nan"),
    1e-310, -1e-310, 1e308, -1e308,
)
_MAX_ADVERSARIAL_ENVS = 4096


@dataclasses.dataclass
class RuleRecord:
    """Per-rule structural classification (metadata, not findings)."""
    name: str
    growth: str            # "expanding" | "contracting" | "neutral"
    lhs_size: int
    rhs_size: int
    finite_math: bool
    envs_checked: int = 0


@dataclasses.dataclass
class RulesCheckResult:
    findings: List[Finding] = dataclasses.field(default_factory=list)
    records: List[RuleRecord] = dataclasses.field(default_factory=list)

    @property
    def rules_checked(self) -> int:
        return len(self.records)


# -- pattern helpers ----------------------------------------------------------
def pattern_vars(pat: Any) -> Set[str]:
    if isinstance(pat, PatVar):
        return {pat.name}
    out: Set[str] = set()
    for ch in pat.children:
        out |= pattern_vars(ch)
    return out


def pattern_size(pat: Any) -> int:
    """Operator-node count (variables are free)."""
    if isinstance(pat, PatVar):
        return 0
    return 1 + sum(pattern_size(ch) for ch in pat.children)


def pattern_ops(pat: Any) -> List[Tuple[str, int]]:
    """(op, arity) of every operator node in the pattern."""
    if isinstance(pat, PatVar):
        return []
    out = [(pat.op, len(pat.children))]
    for ch in pat.children:
        out.extend(pattern_ops(ch))
    return out


def eval_pattern(pat: Any, env: Dict[str, float]):
    """Evaluate a pattern under EVAL_FNS with variables bound by env.

    Variables are bound as ``np.float64`` so every operator follows
    IEEE-754 semantics (0/0 → nan, x/0 → ±inf) instead of raising like
    plain Python floats."""
    import numpy as np
    if isinstance(pat, PatVar):
        return np.float64(env[pat.name])
    args = [eval_pattern(ch, env) for ch in pat.children]
    fn = EVAL_FNS[pat.op]
    with np.errstate(all="ignore"):
        return fn(*args)


# -- environments -------------------------------------------------------------
def _bf16(x: float) -> float:
    """Quantize to the bfloat16 grid (truncate the f32 mantissa to 7
    bits) — every result is an exactly-representable bf16 value, no
    ml_dtypes dependency needed."""
    import numpy as np
    a = np.array([x], dtype=np.float32)
    bits = a.view(np.uint32)
    bits &= np.uint32(0xFFFF0000)
    return float(a[0])


def _random_envs(names: List[str], n: int, seed: int,
                 quantize_bf16: bool = False) -> List[Dict[str, float]]:
    rng = random.Random(seed)
    envs = []
    for _ in range(n):
        env = {}
        for v in names:
            mag = math.exp(rng.uniform(math.log(0.25), math.log(4.0)))
            val = mag if rng.random() < 0.5 else -mag
            env[v] = _bf16(val) if quantize_bf16 else val
        envs.append(env)
    return envs


def _adversarial_envs(names: List[str]) -> Iterable[Dict[str, float]]:
    combos = itertools.product(_SPECIALS, repeat=len(names))
    for combo in itertools.islice(combos, _MAX_ADVERSARIAL_ENVS):
        yield dict(zip(names, combo))


def _fmt(x) -> str:
    import numpy as np
    if isinstance(x, (bool, np.bool_)):
        return str(bool(x))
    try:
        return repr(float(x))
    except (TypeError, ValueError):
        return repr(x)


# -- comparison ---------------------------------------------------------------
def _agree(x, y) -> bool:
    import numpy as np
    if isinstance(x, (bool, np.bool_)) or isinstance(y, (bool, np.bool_)):
        return bool(x) == bool(y)
    try:
        xf, yf = float(x), float(y)
    except (TypeError, ValueError):
        return repr(x) == repr(y)
    if math.isnan(xf) or math.isnan(yf):
        return math.isnan(xf) and math.isnan(yf)
    if math.isinf(xf) or math.isinf(yf):
        return xf == yf
    return abs(xf - yf) <= _ATOL + _RTOL * max(abs(xf), abs(yf))


# -- the pass -----------------------------------------------------------------
def _lint_rule(rule) -> List[Finding]:
    out: List[Finding] = []
    lhs_vars = pattern_vars(rule.lhs)
    rhs_vars = pattern_vars(rule.rhs)
    unbound = sorted(rhs_vars - lhs_vars)
    if unbound:
        out.append(Finding(
            PASS_RULES, "error", "unbound-rhs-var",
            f"RHS variables {unbound} are not bound on the LHS",
            subject=rule.name))
    if isinstance(rule.lhs, PatVar):
        out.append(Finding(
            PASS_RULES, "error", "catchall-lhs",
            "LHS is a bare variable — the rule matches every e-class",
            subject=rule.name))
    for side, pat in (("lhs", rule.lhs), ("rhs", rule.rhs)):
        for op, arity in pattern_ops(pat):
            if op not in ALL_OPS:
                out.append(Finding(
                    PASS_RULES, "error", "unknown-op",
                    f"{side} uses operator {op!r} not in the IR "
                    f"vocabulary", subject=rule.name))
            elif op in _ARITY and _ARITY[op] != arity:
                out.append(Finding(
                    PASS_RULES, "error", "bad-arity",
                    f"{side} applies {op!r} to {arity} operands "
                    f"(expected {_ARITY[op]})", subject=rule.name))
            elif op not in _ARITY:
                out.append(Finding(
                    PASS_RULES, "warning", "structural-op",
                    f"{side} rewrites structural/memory op {op!r} — "
                    f"load/φ/call semantics are not value-only",
                    subject=rule.name))
    return out


def _evaluable(rule) -> bool:
    return all(op in EVAL_FNS
               for op, _ in pattern_ops(rule.lhs) + pattern_ops(rule.rhs))


def _differential(rule, n_random: int, seed: int
                  ) -> Tuple[Optional[Finding], int]:
    """At most one finding per rule: the first tier that disagrees.

    Returns (finding_or_None, environments_checked)."""
    names = sorted(pattern_vars(rule.lhs) | pattern_vars(rule.rhs))
    finite = bool(getattr(rule, "finite_math", False))
    checked = 0
    tiers = [
        ("random", "error", _random_envs(names, n_random, seed)),
        ("bf16", "error",
         _random_envs(names, max(4, n_random // 4), seed + 1,
                      quantize_bf16=True)),
        ("adversarial", "info" if finite else "error",
         _adversarial_envs(names)),
    ]
    for tier, severity, envs in tiers:
        for env in envs:
            checked += 1
            lv = eval_pattern(rule.lhs, env)
            rv = eval_pattern(rule.rhs, env)
            if not _agree(lv, rv):
                code = ("finite-math-gated"
                        if tier == "adversarial" and finite
                        else "unsound-rule")
                msg = (f"LHS≢RHS on {tier} tier: env={env} "
                       f"lhs={_fmt(lv)} rhs={_fmt(rv)}")
                if tier == "adversarial" and finite:
                    msg += " (documented finite_math=True gate)"
                return Finding(PASS_RULES, severity, code, msg,
                               subject=rule.name), checked
    return None, checked


def verify_rules(rules, *, n_random: int = 32,
                 seed: int = 0) -> RulesCheckResult:
    """Run structural lint + differential validation over ``rules``.

    Deterministic; one differential finding max per rule (the clean
    built-in rule sets produce zero error findings — the ``finite_math``
    rules contribute documented ``info`` notes only)."""
    res = RulesCheckResult()
    for rule in rules:
        lint = _lint_rule(rule)
        res.findings.extend(lint)
        delta = pattern_size(rule.rhs) - pattern_size(rule.lhs)
        rec = RuleRecord(
            name=rule.name,
            growth=("expanding" if delta > 0 else
                    "contracting" if delta < 0 else "neutral"),
            lhs_size=pattern_size(rule.lhs),
            rhs_size=pattern_size(rule.rhs),
            finite_math=bool(getattr(rule, "finite_math", False)))
        res.records.append(rec)
        if any(f.severity == "error" for f in lint):
            continue  # structurally broken: differential would misfire
        if not _evaluable(rule):
            res.findings.append(Finding(
                PASS_RULES, "info", "not-evaluable",
                "rule uses operators without a numeric evaluation — "
                "differential validation skipped", subject=rule.name))
            continue
        finding, checked = _differential(rule, n_random, seed)
        rec.envs_checked = checked
        if finding is not None:
            res.findings.append(finding)
    return res
