"""Grid/BlockSpec legality certification — the ``grid`` pass (PR 9).

Every other :mod:`repro.verify` pass audits what happens *inside* one
grid instance (rules, e-graph, statement order, emitted source). This
pass certifies the launch configuration itself: given a
:class:`repro.analysis.access.GridModel` — grid extents plus each
operand's block shape, buffer shape and index map — it statically
proves, per kernel and per candidate configuration:

* **coverage** — every output block is written by exactly one grid
  instance (modulo *inert* axes: a grid axis the output map ignores,
  like flash attention's kv step, legally revisits the same block and
  is projected out first). A missing block — classically the dropped
  remainder tile when ``rows % row_block != 0`` — is
  ``grid-coverage-gap``.
* **disjointness** — no two effective instances write the same output
  block: ``grid-write-race``, the repo's first cross-instance race
  detector.
* **bounds** — no block index escapes the buffer's block lattice
  (``grid-oob-read`` / ``grid-oob-write``). Buffer shapes are
  *post-padding* (``_ceil_to``), so the pad region is modeled as
  in-bounds explicitly rather than waved at.
* **VMEM budget** — the exact working set (block windows × double-buffer
  multiplicity + scratch) fits chip VMEM: ``grid-vmem-overflow``.
  :func:`check_tile_op` additionally compares the exact footprint
  against the legacy ``vmem_estimate`` heuristic and emits a
  ``vmem-heuristic-drift`` warning when the two disagree about fitting
  the autosizing budget — the drift satellite of ISSUE 9.

Certification is exact set arithmetic when the grid is enumerable
(≤ ``ENUM_LIMIT`` instances — every committed kernel) and falls back to
an affine bijection proof for larger grids; configurations that are
neither enumerable nor affine get corner-sampled bounds plus a
``grid-unprovable`` warning (see docs/verification.md for what is and
is not provable).

Consumers: ``verify_tile_op`` (the ``verify=`` wiring in
``make_tile_op``), the grid-audit stage of ``benchmarks/verify_sweep.py``
(13 tile kernels × schedules × emitters + the hand-written
flash-attention / SSD-scan layouts), and the static legality pre-filter
of ``benchmarks/tune.py``.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Dict, List, Optional, Sequence, Tuple

from repro.analysis.access import (ENUM_LIMIT, BlockAccess, GridModel,
                                   IndexMapSummary, affine_bounds,
                                   eval_index, summarize_index_map)
from repro.core.hardware import DEFAULT_CHIP
from .findings import PASS_GRID, Finding

# Coverage lattices larger than this are not materialized even when the
# grid itself is enumerable (a sparse map over a huge buffer): the gap
# check degrades to the unprovable warning instead of an OOM.
_LATTICE_LIMIT = 4 * ENUM_LIMIT
# Corner-sampling cap for the non-enumerable, non-affine fallback.
_CORNER_LIMIT = 1 << 12


@dataclasses.dataclass
class GridCheckResult:
    """Findings + coverage facts of one grid certification."""
    findings: List[Finding] = dataclasses.field(default_factory=list)
    grids_checked: int = 1
    vmem_bytes: int = 0
    provable: bool = True     # False: fell back to sampling somewhere

    def errors(self) -> List[Finding]:
        return [f for f in self.findings if f.severity == "error"]

    @property
    def ok(self) -> bool:
        return not self.errors()


def _f(sev: str, code: str, subject: str, msg: str) -> Finding:
    return Finding(PASS_GRID, sev, code, msg, subject)


def _oob_code(acc: BlockAccess) -> str:
    return "grid-oob-read" if acc.mode == "read" else "grid-oob-write"


def _fmt_env(env: Sequence[int]) -> str:
    return "(" + ", ".join(str(e) for e in env) + ")"


# ---------------------------------------------------------------------------
# Exhaustive certification (the path every committed kernel takes)
# ---------------------------------------------------------------------------
def _certify_enum(model: GridModel, acc: BlockAccess,
                  summ: IndexMapSummary,
                  envs: List[Tuple[int, ...]],
                  findings: List[Finding]) -> None:
    subject = f"{model.name}:{acc.array}"
    nb = acc.n_blocks()
    touch: Dict[Tuple[int, ...], Tuple[int, ...]] = {}
    for env in envs:
        blk = eval_index(summ, env)
        if len(blk) != len(nb):
            findings.append(_f(
                "error", "grid-rank-mismatch", subject,
                f"index map returned rank {len(blk)} for a rank-"
                f"{len(nb)} operand"))
            return
        touch[env] = blk

    oob = [(env, blk) for env, blk in touch.items()
           if any(not (0 <= b < n) for b, n in zip(blk, nb))]
    if oob:
        env, blk = oob[0]
        findings.append(_f(
            "error", _oob_code(acc), subject,
            f"{len(oob)}/{len(envs)} grid instances index outside the "
            f"{nb} block lattice (e.g. instance {_fmt_env(env)} -> block "
            f"{blk}); buffer {acc.array_shape}, block {acc.block_shape}"))
        return   # bounds broke — coverage/race verdicts would only cascade
    if acc.mode == "read":
        return

    # inert axes: varying the axis never moves this write's footprint —
    # a legal revisit (flash attention's kv sweep), not a race candidate
    n_axes = len(model.grid)
    inert = []
    for k in range(n_axes):
        base = {env: touch[env[:k] + (0,) + env[k + 1:]] for env in envs}
        if all(touch[env] == base[env] for env in envs):
            inert.append(k)
    used = [k for k in range(n_axes) if k not in inert]

    seen: Dict[Tuple[int, ...], Tuple[int, ...]] = {}   # block -> eff env
    races = []
    for env in envs:
        eff = tuple(env[k] for k in used)
        blk = touch[env]
        prev = seen.get(blk)
        if prev is None:
            seen[blk] = eff
        elif prev != eff:
            races.append((prev, eff, blk))
    if races:
        a, b, blk = races[0]
        findings.append(_f(
            "error", "grid-write-race", subject,
            f"{len(races)} write-write collision(s) across grid "
            f"instances (e.g. instances {_fmt_env(a)} and {_fmt_env(b)} "
            f"of the non-inert axes {used} both write block {blk})"))
        return   # the colliding map also double-covers; don't double-report

    import math
    lattice = math.prod(nb)
    if lattice > _LATTICE_LIMIT:
        findings.append(_f(
            "warning", "grid-unprovable", subject,
            f"coverage lattice {nb} too large to materialize "
            f"({lattice} blocks > {_LATTICE_LIMIT}); gap check skipped"))
        return
    missing = [blk for blk in itertools.product(*[range(n) for n in nb])
               if blk not in seen]
    if missing:
        findings.append(_f(
            "error", "grid-coverage-gap", subject,
            f"{len(missing)}/{lattice} output block(s) written by no "
            f"grid instance (e.g. block {missing[0]}); grid "
            f"{model.grid}, block {acc.block_shape}, buffer "
            f"{acc.array_shape}"))


# ---------------------------------------------------------------------------
# Affine certification (grids too large to enumerate)
# ---------------------------------------------------------------------------
def _certify_affine(model: GridModel, acc: BlockAccess,
                    summ: IndexMapSummary,
                    findings: List[Finding]) -> bool:
    """True when the access was fully certified without enumeration."""
    if not summ.fully_affine:
        return False
    subject = f"{model.name}:{acc.array}"
    nb = acc.n_blocks()
    dims = summ.dims or []
    if len(dims) != len(nb):
        findings.append(_f(
            "error", "grid-rank-mismatch", subject,
            f"index map returns rank {len(dims)} for a rank-{len(nb)} "
            "operand"))
        return True
    oob_dims = []
    for j, (sym, n) in enumerate(zip(dims, nb)):
        lo, hi = affine_bounds(sym, model.grid)
        if lo < 0 or hi >= n:
            oob_dims.append((j, lo, hi, n))
    if oob_dims:
        j, lo, hi, n = oob_dims[0]
        findings.append(_f(
            "error", _oob_code(acc), subject,
            f"affine block index range [{lo}, {hi}] escapes "
            f"[0, {n}) along dim {j} (block lattice {nb})"))
        return True
    if acc.mode == "read":
        return True

    # bijection proof for the write: each non-inert grid axis must drive
    # exactly one output dim with unit coefficient and zero offset, each
    # output dim at most one axis, and extents must match — then the map
    # is a coordinate embedding: injective (no race) and surjective onto
    # the lattice (no gap)
    used_axes = sorted({k for sym in dims
                        for k, c in enumerate(sym.affine[0]) if c})
    axis_dims: Dict[int, int] = {}
    ok = True
    for j, sym in enumerate(dims):
        coeffs, const = sym.affine
        nz = [(k, c) for k, c in enumerate(coeffs) if c]
        if len(nz) > 1:
            ok = False
            break
        if not nz:
            if const != 0 or nb[j] != 1:
                ok = False
                break
            continue
        k, c = nz[0]
        if c != 1 or const != 0 or k in axis_dims \
                or model.grid[k] != nb[j]:
            ok = False
            break
        axis_dims[k] = j
    if ok and sorted(axis_dims) == used_axes:
        return True
    findings.append(_f(
        "warning", "grid-unprovable", subject,
        f"write map over {model.n_instances} instances is affine but "
        "not a unit coordinate embedding; coverage/disjointness not "
        "proven (bounds were)"))
    return True


def _corner_envs(grid: Sequence[int]) -> List[Tuple[int, ...]]:
    corners = itertools.product(*[(0, g - 1) if g > 1 else (0,)
                                  for g in grid])
    return list(itertools.islice(corners, _CORNER_LIMIT))


# ---------------------------------------------------------------------------
# The checker
# ---------------------------------------------------------------------------
def check_grid(model: GridModel, chip=DEFAULT_CHIP) -> GridCheckResult:
    """Certify one launch configuration; see the module docstring for
    the verdict semantics. Error severities gate CI; warnings mark the
    honestly-unprovable remainder."""
    findings: List[Finding] = []
    provable = True
    n_axes = len(model.grid)
    summaries = [(acc, summarize_index_map(acc.index_map, n_axes))
                 for acc in model.reads + model.writes]
    if model.n_instances <= ENUM_LIMIT:
        envs = list(model.instances())
        for acc, summ in summaries:
            _certify_enum(model, acc, summ, envs, findings)
    else:
        for acc, summ in summaries:
            if _certify_affine(model, acc, summ, findings):
                continue
            provable = False
            subject = f"{model.name}:{acc.array}"
            nb = acc.n_blocks()
            bad = []
            for env in _corner_envs(model.grid):
                try:
                    blk = eval_index(summ, env)
                except Exception:
                    continue
                if len(blk) == len(nb) and any(
                        not (0 <= b < n) for b, n in zip(blk, nb)):
                    bad.append((env, blk))
            if bad:
                env, blk = bad[0]
                findings.append(_f(
                    "error", _oob_code(acc), subject,
                    f"corner sample: instance {_fmt_env(env)} indexes "
                    f"block {blk} outside lattice {nb}"))
            findings.append(_f(
                "warning", "grid-unprovable", subject,
                f"non-affine index map over {model.n_instances} "
                f"instances (> {ENUM_LIMIT}): certified at grid-box "
                "corners only"))
    provable = provable and not any(f.code == "grid-unprovable"
                                    for f in findings)

    vb = model.vmem_bytes
    if vb > chip.vmem_bytes:
        findings.append(_f(
            "error", "grid-vmem-overflow", model.name,
            f"exact VMEM working set {vb} B (blocks x double-buffers + "
            f"scratch) exceeds chip VMEM {chip.vmem_bytes} B"))
    return GridCheckResult(findings=findings, grids_checked=1,
                           vmem_bytes=vb, provable=provable)


# ---------------------------------------------------------------------------
# Model builders: TileOp, flash attention, SSD scan
# ---------------------------------------------------------------------------
def _is_bcast_spec(spec) -> bool:
    """Declared broadcast row (leading extent 1, all dims known) — the
    runtime analogue is ``prod(shape[:-1]) != rows`` in plan_tile_call."""
    shape = getattr(spec, "shape", None)
    if not shape or any(s is None for s in shape):
        return False
    import math
    return math.prod(shape[:-1]) == 1 if len(shape) > 1 else True


def tile_input_shapes(pk, prog, rows: int, d: int) -> List[Tuple[int, ...]]:
    """Synthetic operand shapes for one audit configuration: row-tiled
    arrays get ``(rows, d)``, declared broadcast rows ``(1, d)`` — the
    geometry ``measure.py``'s inputs take after ``_apply_tile_op``'s
    reshape, scaled to the audited feature width."""
    shapes: List[Tuple[int, ...]] = []
    for name in pk.in_arrays:
        spec = prog.arrays.get(name) if prog is not None else None
        shapes.append((1, d) if spec is not None and _is_bcast_spec(spec)
                      else (rows, d))
    return shapes


def tile_call_model(pk, plan, dtype_bytes: int = 4,
                    pipelined: Optional[bool] = None) -> GridModel:
    """Convert one :func:`repro.core.pallasgen.plan_tile_call` plan into
    the checkable :class:`GridModel`. ``pipelined`` doubles the VMEM
    multiplicity of the kernel's ``async_plan`` arrays (block window +
    staging scratch buffer); default = whether the kernel carries one."""
    pipelined = bool(pk.async_arrays) if pipelined is None else pipelined
    async_set = set(pk.async_arrays) if pipelined else set()
    reads = tuple(
        BlockAccess(e.name, "read", e.block_shape, e.buffer_shape,
                    e.index_map, dtype_bytes=dtype_bytes,
                    buffers=2 if e.name in async_set else 1)
        for e in plan.inputs)
    writes = tuple(
        BlockAccess(e.name, "write", e.block_shape, e.buffer_shape,
                    e.index_map, dtype_bytes=dtype_bytes)
        for e in plan.outputs)
    return GridModel(pk.name, plan.grid, reads, writes)


def check_tile_kernel_grid(pk, prog, row_block: Optional[int] = None,
                           rows: Optional[int] = None,
                           d: Optional[int] = None,
                           chip=DEFAULT_CHIP) -> GridCheckResult:
    """Certify one emitted :class:`~repro.core.pallasgen.PallasKernel`'s
    launch plan at a given ``row_block`` (default: what ``make_tile_op``
    would autosize from the declared geometry).

    ``rows`` defaults to a geometry that exercises the padded remainder
    tile (``rows % row_block != 0``); ``d`` to the program's declared
    feature width. On top of :func:`check_grid`, compares the exact
    footprint with the legacy ``vmem_estimate(row_block, 256, n_tiles,
    4)`` heuristic and reports ``vmem-heuristic-drift`` when they
    disagree about fitting the autosizing budget (suppressed when the
    hard overflow already fired — the error subsumes the drift note)."""
    from repro.core.pallasgen import (_declared_dtype_bytes,
                                      _declared_feature_dim,
                                      pick_row_block, plan_tile_call,
                                      vmem_estimate)
    n_tiles = len(pk.in_arrays) + len(pk.out_arrays) + 2
    rb = row_block or (pick_row_block(
        (_declared_feature_dim(prog) if prog is not None else None) or 256,
        n_tiles,
        _declared_dtype_bytes(prog) if prog is not None else 4,
        chip=chip))
    if d is None:
        d = (_declared_feature_dim(prog) if prog is not None else None) \
            or 256
    if rows is None:
        rows = 2 * rb + max(1, rb // 2)   # forces a ragged remainder tile
    dtype_bytes = _declared_dtype_bytes(prog) if prog is not None else 4
    plan = plan_tile_call(pk, tile_input_shapes(pk, prog, rows, d), rb)
    model = tile_call_model(pk, plan, dtype_bytes=dtype_bytes)
    res = check_grid(model, chip)

    overflow = any(f.code == "grid-vmem-overflow" for f in res.findings)
    if not overflow:
        legacy = vmem_estimate(plan.row_block, 256, n_tiles, 4)
        budget = chip.vmem_bytes // 4
        legacy_fits, exact_fits = legacy <= budget, res.vmem_bytes <= budget
        if legacy_fits != exact_fits:
            verdict = ("under-budgeted: the heuristic admits a config "
                       "whose exact footprint busts the autosizing budget"
                       if legacy_fits else
                       "over-budgeted: the heuristic rejects a config "
                       "whose exact footprint fits")
            res.findings.append(_f(
                "warning", "vmem-heuristic-drift", model.name,
                f"legacy vmem_estimate {legacy} B vs exact "
                f"{res.vmem_bytes} B against budget {budget} B — "
                f"{verdict}"))
    return res


def check_tile_op(op, rows: Optional[int] = None, d: Optional[int] = None,
                  row_block: Optional[int] = None,
                  chip=DEFAULT_CHIP) -> GridCheckResult:
    """Certify one :class:`~repro.core.pallasgen.TileOp` configuration —
    :func:`check_tile_kernel_grid` at the op's own ``row_block`` (or an
    explicit candidate, which is how ``benchmarks/tune.py`` pre-filters
    its search space)."""
    prog = op.sk.ssa.prog if getattr(op, "sk", None) is not None else None
    return check_tile_kernel_grid(op.pk, prog,
                                  row_block=row_block or op.row_block,
                                  rows=rows, d=d, chip=chip)


def flash_attention_model(B: int, H: int, KH: int, S: int, D: int,
                          q_block: int = 128, kv_block: int = 128,
                          dtype_bytes: int = 4) -> GridModel:
    """The hand-written flash-attention launch as a checkable model
    (shared layout: :func:`repro.kernels.flash_attention.attention_layout`)."""
    from repro.kernels.flash_attention import attention_layout
    lay = attention_layout(B, H, KH, S, D, min(q_block, S),
                           min(kv_block, S))
    reads = tuple(BlockAccess(n, "read", *lay[n], dtype_bytes=dtype_bytes)
                  for n in ("q", "k", "v"))
    writes = (BlockAccess("o", "write", *lay["o"],
                          dtype_bytes=dtype_bytes),)
    return GridModel("flash_attention", lay["grid"], reads, writes,
                     scratch_bytes=lay["scratch_bytes"])


def ssd_scan_model(B: int, H: int, S: int, P: int, N: int,
                   chunk: int = 128,
                   dtype_bytes: int = 4) -> GridModel:
    """The hand-written SSD-scan launch as a checkable model (shared
    layout: :func:`repro.kernels.ssd_scan.ssd_layout`)."""
    from repro.kernels.ssd_scan import ssd_layout
    lay = ssd_layout(B * H, S, P, N, min(chunk, S))
    reads = tuple(BlockAccess(n, "read", *lay[n], dtype_bytes=dtype_bytes)
                  for n in ("x", "dt", "a_log", "b", "c", "d_skip"))
    writes = (BlockAccess("o", "write", *lay["o"],
                          dtype_bytes=dtype_bytes),)
    return GridModel("ssd_scan", lay["grid"], reads, writes,
                     scratch_bytes=lay["scratch_bytes"])
