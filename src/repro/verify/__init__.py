"""repro.verify — static soundness & legality analysis (PR 7).

Four passes over the saturator's artifacts, each reporting
severity-tagged :class:`Finding`\\ s:

1. **rules** (:mod:`.rules_check`) — structural lint + random/bf16/
   adversarial differential validation that every rewrite rule is an
   actual equality;
2. **egraph** (:mod:`.egraph_check`) — union-find, hashcons/congruence
   closure and analysis-consistency invariants
   (= ``EGraph.check_invariants()``);
3. **schedule** (:mod:`.schedule_check`) — an independent re-derivation
   of RAW/WAR/store-store dependences certifying emitted statement
   orders as legal topological orders (an N-version check against
   ``repro.core.schedule``, not a call into it);
4. **codegen** (:mod:`.codegen_check`) — AST analysis of emitted
   JAX/Pallas sources (bounds, aliasing, use-before-def, dead loads,
   overlap-distance lint);
5. **grid** (:mod:`.grid_check`, PR 9) — symbolic certification of the
   launch configuration itself: BlockSpec index maps are evaluated over
   symbolic grid coordinates (:mod:`repro.analysis.access`) and the
   resulting footprints proven coverage-complete, write-disjoint,
   in-bounds (pad region modeled), and inside the exact VMEM budget.

``SaturatorConfig(verify="cheap"|"full")`` runs 2–4 on every pipeline
product (``"full"`` also re-validates the active rule set and certifies
reconstructed orders for legacy emitters); findings are counted in
``repro.core.telemetry`` and surfaced by ``benchmarks/verify_sweep.py``.
"""
from __future__ import annotations

from typing import Optional

from .codegen_check import check_generated, shapes_of
from .egraph_check import check_egraph
from .findings import (PASS_CODEGEN, PASS_EGRAPH, PASS_GRID, PASS_RULES,
                       PASS_SCHEDULE, SEVERITIES, Finding, VerifyReport)
from .grid_check import (GridCheckResult, check_grid, check_tile_op,
                         flash_attention_model, ssd_scan_model,
                         tile_call_model)
from .rules_check import RuleRecord, RulesCheckResult, verify_rules
from .schedule_check import (ScheduleCheckResult, verify_async_plan,
                             verify_schedule)

VERIFY_LEVELS = ("off", "cheap", "full")

__all__ = [
    "Finding", "VerifyReport", "SEVERITIES", "VERIFY_LEVELS",
    "PASS_RULES", "PASS_EGRAPH", "PASS_SCHEDULE", "PASS_CODEGEN",
    "PASS_GRID",
    "verify_rules", "RulesCheckResult", "RuleRecord",
    "check_egraph", "verify_schedule", "ScheduleCheckResult",
    "verify_async_plan", "check_generated", "shapes_of",
    "check_grid", "check_tile_op", "tile_call_model", "GridCheckResult",
    "flash_attention_model", "ssd_scan_model",
    "verify_saturated", "verify_pallas_kernel", "verify_tile_op",
]


def verify_saturated(sk, level: Optional[str] = None) -> VerifyReport:
    """Run the static passes over one pipeline product.

    ``level`` defaults to ``sk.config.verify``. ``"cheap"`` checks the
    e-graph, certifies the schedule actually attached to the generated
    kernel, and lints the emitted source; ``"full"`` additionally
    re-validates the active rule set differentially and reconstructs a
    searchless schedule for legacy (source/bulk) emissions so those
    orders are certified too. Findings are recorded in the process
    telemetry; the report is also attached to ``sk.verify_report`` by
    the pipeline."""
    level = sk.config.verify if level is None else level
    if level not in VERIFY_LEVELS:
        raise ValueError(f"verify level must be one of {VERIFY_LEVELS}, "
                         f"got {level!r}")
    rep = VerifyReport()
    if level == "off":
        return rep

    # pass 2: e-graph invariants (post run_rules / post graft)
    rep.extend(check_egraph(sk.ssa.egraph))
    rep.egraphs_checked += 1

    # pass 3: schedule legality (explicit orders always; at "full",
    # legacy implicit emissions get a searchless reconstruction so the
    # certified order is exactly what a cache entry would replay)
    sched = sk.kernel.schedule
    if sched is None and level == "full":
        from repro.core.pipeline import _schedule_cm
        from repro.core.schedule import compute_schedule
        try:
            sched = compute_schedule(
                sk.ssa, dict(sk.extraction.choice),
                mode=sk.config.schedule_mode,
                cost_model=_schedule_cm(sk.config, sk.ssa.prog,
                                        sk.ssa.egraph),
                move_budget=0)
        except ValueError as e:
            rep.add(Finding(
                PASS_SCHEDULE, "error", "unschedulable",
                f"no legal order could be reconstructed: {e}"))
    if sched is not None:
        scr = verify_schedule(sk.ssa, sk.extraction.choice, sched)
        rep.extend(scr.findings)
        rep.schedules_certified += scr.regions_certified

    # pass 4: emitted-source analysis
    rep.extend(check_generated(sk.kernel.source, shapes_of(sk.ssa.prog),
                               subject=sk.kernel.name))
    rep.sources_checked += 1

    # pass 1 (full only — rule sets don't change per kernel, so cheap
    # runs leave this to verify_sweep / the test suite)
    if level == "full":
        rres = verify_rules(sk.config.rules())
        rep.extend(rres.findings)
        rep.rules_checked += rres.rules_checked

    from repro.core.telemetry import telemetry
    telemetry().record_verify(rep)
    return rep


def verify_pallas_kernel(pk, ssa) -> VerifyReport:
    """Certify one emitted :class:`PallasKernel` (PR 8).

    Lints the kernel source (and, for the pipelined emitter, the
    synchronous fallback source under the ``:fallback`` subject — the
    async-pairing checks in :mod:`.codegen_check` run on both), then
    cross-checks the recorded async-copy plan against the schedule with
    :func:`verify_async_plan`: start slots, wait domination, semaphore
    parity and the ≤2-in-flight double-buffer bound."""
    rep = VerifyReport()
    shapes = shapes_of(ssa.prog)
    rep.extend(check_generated(pk.source, shapes, subject=pk.name))
    rep.sources_checked += 1
    if pk.fallback_source is not None:
        rep.extend(check_generated(pk.fallback_source, shapes,
                                   subject=f"{pk.name}:fallback"))
        rep.sources_checked += 1
    if pk.async_plan and pk.schedule is not None:
        rep.extend(verify_async_plan(ssa, pk.schedule, pk.async_plan))
    from repro.core.telemetry import telemetry
    telemetry().record_verify(rep)
    return rep


def verify_tile_op(op, rows: Optional[int] = None,
                   chip=None) -> VerifyReport:
    """Certify one :class:`~repro.core.pallasgen.TileOp`'s launch plan
    (PR 9): the grid pass over exactly the :func:`plan_tile_call` plan
    the op executes — coverage, write disjointness, bounds with the pad
    region modeled, exact VMEM fit, and the legacy-heuristic drift
    comparison. Wired into ``make_tile_op`` for every ``verify`` level
    above ``"off"``; findings land in the process telemetry like every
    other pass."""
    kwargs = {} if chip is None else {"chip": chip}
    res = check_tile_op(op, rows=rows, **kwargs)
    rep = VerifyReport()
    rep.extend(res.findings)
    rep.grids_checked += res.grids_checked
    from repro.core.telemetry import telemetry
    telemetry().record_verify(rep)
    return rep
