"""Generated-code pass: AST-level analysis of emitted kernel sources.

All emitters (:class:`repro.core.codegen.JaxCodeGenerator` and the
:mod:`repro.core.pallasgen` generators) produce Python source
that is ``exec``'d and shipped. This pass parses that source with
:mod:`ast` and checks it against the *declared* program geometry —
defects here escape the exec round-trip (Python compiles ``x[999]``
happily) and only explode inside ``pallas_call`` or, worse, silently
read the wrong tile:

* **out-of-bounds tile indexing** (``error``) — constant indices vs the
  declared :class:`~repro.core.dsl.ArraySpec` shape, through the alias
  chain (``_v3 = x`` / ``_v3 = x_ref[...]`` carry x's shape), including
  ``arr.at[i].set(v)`` stores and rank overflow;
* **use-before-def** (``error``) — a name read before any binding, with
  closure semantics for nested loop bodies (``def _loopN`` may read
  anything its enclosing function ever binds, since it runs at
  ``fori_loop`` time);
* **ref aliasing** (``warning``) — ``inout`` arrays are bound to both
  ``{a}_ref`` and ``{a}_oref`` over the same buffer: reading the
  ``_ref`` after the ``_oref`` was written observes the new value;
* **overwritten stores** (``warning``) — two writes to one ``_oref``
  with the same static index and no intervening read of that array;
* **dead loads** (``warning``) — a ``_vN`` load temp never consumed;
* **memory-access order** (``info``) — the overlap-distance lint: loads
  whose first consumer is the immediately following statement leave the
  scheduler no latency to hide (one aggregated note per function);
* **async copy pairing** (``error``) — for the PR-8 pipelined Pallas
  emitter: every ``pltpu.make_async_copy`` start has exactly one wait
  (``unmatched-async-start`` / ``unmatched-async-wait``), the wait
  dominates the first read of the destination buffer
  (``async-wait-order``), semaphore parity alternates with copy index
  (``async-buffer-parity``), and no two copies share a semaphore while
  in flight (``async-sem-overlap``) — the double-buffer invariant.
"""
from __future__ import annotations

import ast
import re
from typing import Any, Dict, List, Optional, Set, Tuple

from .findings import PASS_CODEGEN, Finding

Shape = Optional[Tuple[Optional[int], ...]]

_TEMP_RE = re.compile(r"_v\d+$")
_CP_RE = re.compile(r"_cp(\d+)$")
_SEM_RE = re.compile(r"_sem(\d+)$")
_GLOBALS = {
    "jax", "jnp", "lax", "pltpu", "_rothalf", "_calls",
    "True", "False", "None", "range", "len", "float", "int", "tuple",
}


def shapes_of(prog) -> Dict[str, Shape]:
    """Declared shapes of a :class:`~repro.core.dsl.KernelProgram`."""
    return {name: spec.shape for name, spec in prog.arrays.items()}


def _base_array(name: str, shapes: Dict[str, Shape]) -> Optional[str]:
    """Resolve a source identifier to a declared array (Pallas refs
    strip their ``_ref``/``_oref`` suffix, pipelined staging buffers
    their ``_buf``)."""
    if name in shapes:
        return name
    for suf in ("_oref", "_ref", "_buf"):
        if name.endswith(suf) and name[: -len(suf)] in shapes:
            return name[: -len(suf)]
    return None


def _sub_base(node: ast.expr) -> Optional[str]:
    """Identifier a subscript indexes: ``x[...]`` or ``x.at[...]``."""
    if isinstance(node, ast.Name):
        return node.id
    if isinstance(node, ast.Attribute) and node.attr == "at" and \
            isinstance(node.value, ast.Name):
        return node.value.id
    return None


def _const_int(node: ast.expr) -> Optional[int]:
    if isinstance(node, ast.Constant) and isinstance(node.value, int) \
            and not isinstance(node.value, bool):
        return node.value
    if isinstance(node, ast.UnaryOp) and isinstance(node.op, ast.USub):
        inner = _const_int(node.operand)
        return None if inner is None else -inner
    return None


def _index_elts(sub: ast.Subscript) -> List[ast.expr]:
    sl = sub.slice
    return list(sl.elts) if isinstance(sl, ast.Tuple) else [sl]


def _is_ellipsis(node: ast.expr) -> bool:
    return isinstance(node, ast.Constant) and node.value is Ellipsis


def check_generated(source: str, shapes: Dict[str, Shape], *,
                    subject: str = "") -> List[Finding]:
    """Analyze one emitted kernel source against declared ``shapes``."""
    try:
        tree = ast.parse(source)
    except SyntaxError as e:
        return [Finding(PASS_CODEGEN, "error", "syntax-error",
                        f"emitted source does not parse: {e}",
                        subject=subject)]
    out: List[Finding] = []
    module_fns = {n.name for n in tree.body
                  if isinstance(n, ast.FunctionDef)}
    for fn in tree.body:
        # the prelude's _rothalf helper is not generated code
        if isinstance(fn, ast.FunctionDef) and fn.name != "_rothalf":
            tag = subject or fn.name
            out.extend(_check_fn(fn, shapes, module_fns, tag))
    return out


# -- per-function analysis ----------------------------------------------------
def _assigned_names(stmts: List[ast.stmt]) -> Set[str]:
    """Every name a statement list binds, at any nesting depth."""
    out: Set[str] = set()
    for st in stmts:
        for node in ast.walk(st):
            if isinstance(node, ast.Name) and \
                    isinstance(node.ctx, ast.Store):
                out.add(node.id)
            elif isinstance(node, ast.FunctionDef):
                out.add(node.name)
                out.update(a.arg for a in node.args.args)
    return out


def _loads_outside_nested(st: ast.stmt) -> List[ast.Name]:
    """Name loads of one statement, excluding nested-function bodies
    (those are checked with closure semantics separately)."""
    found: List[ast.Name] = []

    def walk(node: ast.AST):
        if isinstance(node, ast.FunctionDef) and node is not st:
            return
        if isinstance(node, ast.Name) and isinstance(node.ctx, ast.Load):
            found.append(node)
        for ch in ast.iter_child_nodes(node):
            walk(ch)
    walk(st)
    return found


def _shape_env(fn: ast.FunctionDef,
               shapes: Dict[str, Shape]) -> Dict[str, Shape]:
    """Known static shapes per identifier: declared arrays, their
    Pallas refs, and whole-value aliases (``_v3 = x`` /
    ``_v3 = x_ref[...]`` / ``o = x.at[i].set(v)``)."""
    env: Dict[str, Shape] = {}
    for name, shp in shapes.items():
        env[name] = shp
        env[f"{name}_ref"] = shp
        env[f"{name}_oref"] = shp
        env[f"{name}_buf"] = shp
    changed = True
    while changed:                       # aliases of aliases
        changed = False
        for node in ast.walk(fn):
            if not (isinstance(node, ast.Assign)
                    and len(node.targets) == 1
                    and isinstance(node.targets[0], ast.Name)):
                continue
            tgt = node.targets[0].id
            if tgt in env:
                continue
            src: Optional[str] = None
            val = node.value
            if isinstance(val, ast.Name):
                src = val.id             # _v3 = x
            elif isinstance(val, ast.Subscript) and \
                    len(_index_elts(val)) == 1 and \
                    _is_ellipsis(_index_elts(val)[0]):
                src = _sub_base(val.value)   # _v3 = x_ref[...]
            elif isinstance(val, ast.Call) and \
                    isinstance(val.func, ast.Attribute) and \
                    val.func.attr == "set" and \
                    isinstance(val.func.value, ast.Subscript):
                src = _sub_base(val.func.value.value)  # o = x.at[i].set(v)
            if src is not None and src in env:
                env[tgt] = env[src]
                changed = True
    return env


def _check_fn(fn: ast.FunctionDef, shapes: Dict[str, Shape],
              module_fns: Set[str], tag: str) -> List[Finding]:
    out: List[Finding] = []
    env = _shape_env(fn, shapes)

    # ---- out-of-bounds / rank check over every subscript ------------------
    for node in ast.walk(fn):
        if not isinstance(node, ast.Subscript):
            continue
        base = _sub_base(node.value)
        shp = env.get(base) if base is not None else None
        if shp is None:
            continue
        elts = _index_elts(node)
        if len(elts) == 1 and _is_ellipsis(elts[0]):
            continue                      # whole-tile ref access
        if len(elts) > len(shp):
            out.append(Finding(
                PASS_CODEGEN, "error", "rank-mismatch",
                f"{base} has rank {len(shp)} but is indexed with "
                f"{len(elts)} subscripts", subject=f"{tag}:{base}"))
            continue
        for dim, (elt, extent) in enumerate(zip(elts, shp)):
            idx = _const_int(elt)
            if idx is None or extent is None:
                continue                  # dynamic index / symbolic dim
            if not (-extent <= idx < extent):
                out.append(Finding(
                    PASS_CODEGEN, "error", "oob-index",
                    f"constant index {idx} out of bounds for {base} "
                    f"dim {dim} (extent {extent})",
                    subject=f"{tag}:{base}"))

    # ---- use-before-def (closure-aware) -----------------------------------
    def scan(stmts: List[ast.stmt], defined: Set[str], closure: Set[str]):
        for st in stmts:
            if isinstance(st, ast.FunctionDef):
                # body runs later: it may read anything the enclosing
                # scope ever binds (fori_loop carries, later temps)
                inner = set(a.arg for a in st.args.args)
                scan(st.body, inner,
                     closure | defined | _assigned_names(stmts))
                defined.add(st.name)
                continue
            for nm in _loads_outside_nested(st):
                name = nm.id
                if name in defined or name in closure or \
                        name in _GLOBALS or name in module_fns:
                    continue
                out.append(Finding(
                    PASS_CODEGEN, "error", "use-before-def",
                    f"{name!r} is read at line {nm.lineno} before any "
                    f"definition", subject=f"{tag}:{name}"))
                defined.add(name)        # report each name once
            for node in ast.walk(st):
                if isinstance(node, ast.Name) and \
                        isinstance(node.ctx, ast.Store):
                    defined.add(node.id)

    scan(fn.body, {a.arg for a in fn.args.args}, set())

    # ---- linear top-level walk: stores, aliasing, dead loads, overlap -----
    stmts = fn.body
    all_loads: Dict[str, List[int]] = {}     # name -> stmt positions read
    load_defs: Dict[str, int] = {}           # _vN load temp -> position
    writes: Dict[str, List[Tuple[int, str]]] = {}  # array -> (pos, idx repr)
    reads_of_array: Dict[str, List[int]] = {}
    first_oref_write: Dict[str, int] = {}

    for pos, st in enumerate(stmts):
        for nm in _loads_outside_nested(st) + [
                n for f_ in ast.walk(st) if isinstance(f_, ast.FunctionDef)
                for n in ast.walk(f_)
                if isinstance(n, ast.Name) and isinstance(n.ctx, ast.Load)]:
            all_loads.setdefault(nm.id, []).append(pos)
            base = _base_array(nm.id, shapes)
            if base is not None:
                reads_of_array.setdefault(base, []).append(pos)
        if not isinstance(st, ast.Assign) or len(st.targets) != 1:
            continue
        tgt = st.targets[0]
        if isinstance(tgt, ast.Subscript):            # x_oref[...] = v
            ref = _sub_base(tgt.value)
            base = _base_array(ref, shapes) if ref else None
            if base is not None:
                writes.setdefault(base, []).append(
                    (pos, ast.dump(tgt.slice)))
                if ref and ref.endswith("_oref"):
                    first_oref_write.setdefault(base, pos)
        elif isinstance(tgt, ast.Name) and _TEMP_RE.match(tgt.id):
            val = st.value
            is_load = (isinstance(val, ast.Name)
                       and _base_array(val.id, shapes) is not None) or \
                      (isinstance(val, ast.Subscript)
                       and _sub_base(val.value) is not None
                       and _base_array(_sub_base(val.value), shapes)
                       is not None)
            if is_load:
                load_defs[tgt.id] = pos

    # inout aliasing: _ref read after the aliased _oref was written
    for base, wpos in first_oref_write.items():
        ref_reads = [p for p in all_loads.get(f"{base}_ref", [])
                     if p > wpos]
        if ref_reads:
            out.append(Finding(
                PASS_CODEGEN, "warning", "aliased-read-after-write",
                f"{base}_ref is read at statement {ref_reads[0]} after "
                f"{base}_oref was written at statement {wpos} — inout "
                f"refs alias one buffer", subject=f"{tag}:{base}"))

    # overwritten stores: same static index, no intervening read
    for base, ws in writes.items():
        for (p1, i1), (p2, i2) in zip(ws, ws[1:]):
            if i1 != i2:
                continue
            between = [p for p in reads_of_array.get(base, [])
                       if p1 < p <= p2]
            if not between:
                out.append(Finding(
                    PASS_CODEGEN, "warning", "overwritten-store",
                    f"store to {base} at statement {p1} is overwritten "
                    f"at {p2} with no intervening read",
                    subject=f"{tag}:{base}"))

    # dead loads + overlap-distance lint
    zero_overlap = 0
    for name, pos in load_defs.items():
        later = [p for p in all_loads.get(name, []) if p > pos]
        if not later:
            out.append(Finding(
                PASS_CODEGEN, "warning", "dead-load",
                f"load temp {name} (statement {pos}) is never read",
                subject=f"{tag}:{name}"))
        elif later[0] == pos + 1:
            zero_overlap += 1
    if zero_overlap:
        out.append(Finding(
            PASS_CODEGEN, "info", "zero-overlap-load",
            f"{zero_overlap} of {len(load_defs)} loads are consumed by "
            f"the immediately following statement (no latency-hiding "
            f"distance)", subject=tag))

    out.extend(_check_async(fn, tag))
    return out


# -- async copy pairing (pipelined Pallas emitter) ----------------------------
def _check_async(fn: ast.FunctionDef, tag: str) -> List[Finding]:
    """Certify the double-buffered async-copy discipline of a pipelined
    Pallas body: exactly one wait per start, waits dominating the first
    destination-buffer read, ``index % 2`` semaphore parity, and at most
    one copy in flight per semaphore."""
    out: List[Finding] = []
    copies: Dict[int, Dict[str, Any]] = {}
    buf_first_read: Dict[str, int] = {}
    for pos, st in enumerate(fn.body):
        if (isinstance(st, ast.Assign) and len(st.targets) == 1
                and isinstance(st.targets[0], ast.Name)):
            m = _CP_RE.match(st.targets[0].id)
            val = st.value
            if (m and isinstance(val, ast.Call)
                    and isinstance(val.func, ast.Attribute)
                    and val.func.attr == "make_async_copy"):
                k = int(m.group(1))
                sem = None
                if len(val.args) >= 3 and isinstance(val.args[2], ast.Name):
                    sm = _SEM_RE.match(val.args[2].id)
                    sem = int(sm.group(1)) if sm else None
                buf = (val.args[1].id if len(val.args) >= 2
                       and isinstance(val.args[1], ast.Name) else None)
                copies[k] = {"pos": pos, "start": None, "waits": [],
                             "buf": buf, "sem": sem}
                continue
        if (isinstance(st, ast.Expr) and isinstance(st.value, ast.Call)
                and isinstance(st.value.func, ast.Attribute)
                and isinstance(st.value.func.value, ast.Name)
                and st.value.func.attr in ("start", "wait")):
            m = _CP_RE.match(st.value.func.value.id)
            if m:
                k = int(m.group(1))
                if k not in copies:
                    out.append(Finding(
                        PASS_CODEGEN, "error", "unmatched-async-wait",
                        f"_cp{k}.{st.value.func.attr}() at statement "
                        f"{pos} has no matching make_async_copy",
                        subject=f"{tag}:_cp{k}"))
                elif st.value.func.attr == "start":
                    copies[k]["start"] = pos
                else:
                    copies[k]["waits"].append(pos)
                continue
        for nm in _loads_outside_nested(st):
            if nm.id.endswith("_buf"):
                buf_first_read.setdefault(nm.id, pos)
    for k in sorted(copies):
        c = copies[k]
        subj = f"{tag}:_cp{k}"
        if c["sem"] is not None and c["sem"] != k % 2:
            out.append(Finding(
                PASS_CODEGEN, "error", "async-buffer-parity",
                f"async copy _cp{k} uses _sem{c['sem']}; double "
                f"buffering requires parity _sem{k % 2}", subject=subj))
        if c["start"] is None:
            out.append(Finding(
                PASS_CODEGEN, "error", "unmatched-async-wait",
                f"async copy _cp{k} is created but never started",
                subject=subj))
        if not c["waits"]:
            out.append(Finding(
                PASS_CODEGEN, "error", "unmatched-async-start",
                f"async copy _cp{k} ({c['buf']}) is started but never "
                f"waited — its buffer contents are undefined at use",
                subject=subj))
            continue
        if len(c["waits"]) > 1:
            out.append(Finding(
                PASS_CODEGEN, "error", "unmatched-async-wait",
                f"async copy _cp{k} is waited {len(c['waits'])} times",
                subject=subj))
        w = c["waits"][0]
        if c["start"] is not None and w <= c["start"]:
            out.append(Finding(
                PASS_CODEGEN, "error", "async-wait-order",
                f"async copy _cp{k} waits at statement {w}, before its "
                f"start at {c['start']}", subject=subj))
        first_read = buf_first_read.get(c["buf"] or "")
        if first_read is not None and first_read < w:
            out.append(Finding(
                PASS_CODEGEN, "error", "async-wait-order",
                f"{c['buf']} is read at statement {first_read} before "
                f"_cp{k}.wait() at {w} — the wait must dominate the "
                f"first use", subject=subj))
    done = sorted(k for k in copies
                  if copies[k]["start"] is not None and copies[k]["waits"])
    for i, k1 in enumerate(done):
        for k2 in done[i + 1:]:
            if copies[k1]["sem"] is None or \
                    copies[k1]["sem"] != copies[k2]["sem"]:
                continue
            if copies[k2]["start"] < copies[k1]["waits"][0]:
                out.append(Finding(
                    PASS_CODEGEN, "error", "async-sem-overlap",
                    f"async copies _cp{k1} and _cp{k2} are both in "
                    f"flight on _sem{copies[k1]['sem']} (start "
                    f"{copies[k2]['start']} before wait "
                    f"{copies[k1]['waits'][0]})",
                    subject=f"{tag}:_sem{copies[k1]['sem']}"))
    return out
