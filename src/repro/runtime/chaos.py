"""Deterministic, seeded fault injection for the saturator stack.

A :class:`FaultPlan` names *injection sites* — fixed points in the
pipeline (cache I/O, rule application, e-graph budgets, codegen
``exec``, verification, the schedule search) where a fault is raised
when the plan says so. Sites call :func:`chaos_point` /
:func:`maybe_raise`; with no plan installed those are near-free no-ops,
so production paths pay nothing.

Determinism contract: whether occurrence *n* of a site fires depends
only on ``(plan.seed, site, n)`` via sha256 — never on wall clock,
``random``, or hash order — so a chaos run replays bit-identically
under any ``PYTHONHASHSEED`` (``benchmarks/chaos_sweep.py`` gates on
this).

Activation: ``install_plan()`` / the ``plan_scope()`` context manager
(what ``SaturatorConfig.guard_cfg.chaos`` uses), or the ``REPRO_CHAOS``
environment variable (see :func:`plan_from_env`).

The module also hosts :class:`ScheduledFaults` — the seeded one-shot
keyed registry behind :class:`repro.runtime.ft.FailureInjector`, so the
training-loop fault schedule and the saturator chaos sites share one
injection mechanism and one telemetry stream.

No top-level repro imports: deep core modules (egraph/beam/schedule/
rules/codegen) import this module at module scope without cycles.
"""
from __future__ import annotations

import dataclasses
import hashlib
import os
import threading
from contextlib import contextmanager
from typing import Any, Dict, List, Optional, Tuple

ENV_VAR = "REPRO_CHAOS"

# Every site the stack exposes. Raising styles differ on purpose:
# cache sites raise *real* OSErrors inside the store's own try blocks
# (exercising the production handlers), the rest raise InjectedFault
# (caught by the degradation ladder in repro.core.pipeline).
FAULT_SITES = (
    "cache_read_io",    # OSError while reading a cache entry
    "cache_write_io",   # OSError (ENOSPC) in the atomic-write path
    "cache_corrupt",    # entry bytes tampered -> digest mismatch
    "rule_raise",       # a rewrite rule raises mid-saturation
    "egraph_budget",    # e-graph budget exhaustion during saturation
    "exec_fail",        # codegen exec() of the generated source fails
    "verify_error",     # the static verifier raises
    "slow_stage",       # the cost schedule search stalls past deadline
    "train_host_loss",  # ft.py: simulated host loss in the train loop
)


class InjectedFault(RuntimeError):
    """A fault raised by the chaos harness (never by production code)."""

    def __init__(self, site: str, detail: str = ""):
        super().__init__(f"injected fault at {site}"
                         + (f": {detail}" if detail else ""))
        self.site = site


@dataclasses.dataclass(frozen=True)
class FaultPlan:
    """Which sites fire, how often, and for which kernels.

    ``max_fires`` bounds fires *per site* (None = unlimited);
    ``probability`` < 1 makes occurrence *n* of a site fire iff the
    deterministic hash of ``(seed, site, n)`` lands under it; a
    ``kernels`` filter restricts firing to those kernel names (sites
    reached outside any kernel context always pass the filter when it
    is unset, never when it is set)."""
    sites: Tuple[str, ...]
    seed: int = 0
    max_fires: Optional[int] = 1
    kernels: Optional[Tuple[str, ...]] = None
    probability: float = 1.0

    def __post_init__(self):
        unknown = sorted(set(self.sites) - set(FAULT_SITES))
        if unknown:
            raise ValueError(f"unknown fault site(s) {unknown}; "
                             f"valid: {FAULT_SITES}")


_LOCK = threading.Lock()
_PLAN: Optional[FaultPlan] = None
_OCCURRENCES: Dict[str, int] = {}
_FIRES: Dict[str, int] = {}
# env-plan cache: (raw REPRO_CHAOS value, parsed plan)
_ENV_CACHE: Tuple[Optional[str], Optional[FaultPlan]] = (None, None)

# thread-local kernel context, pushed by SaturationGuard.activate()
_TLS = threading.local()


def _tel():
    from repro.core.telemetry import telemetry
    return telemetry()


def _u01(seed: int, site: str, occurrence: int) -> float:
    h = hashlib.sha256(f"{seed}:{site}:{occurrence}".encode()).digest()
    return int.from_bytes(h[:8], "big") / 2.0 ** 64


def install_plan(plan: Optional[FaultPlan]):
    """Install ``plan`` process-wide (None = clear). Resets fire/
    occurrence counters so expectations are per-installation."""
    global _PLAN
    with _LOCK:
        _PLAN = plan
        _OCCURRENCES.clear()
        _FIRES.clear()


def clear_plan():
    install_plan(None)


def active_plan() -> Optional[FaultPlan]:
    """The installed plan, else the ``REPRO_CHAOS`` environment plan."""
    if _PLAN is not None:
        return _PLAN
    return _env_plan()


def _env_plan() -> Optional[FaultPlan]:
    global _ENV_CACHE
    raw = os.environ.get(ENV_VAR) or None
    cached_raw, cached_plan = _ENV_CACHE
    if raw == cached_raw:
        return cached_plan
    plan = plan_from_env(raw) if raw else None
    with _LOCK:
        _ENV_CACHE = (raw, plan)
    return plan


def plan_from_env(spec: str) -> FaultPlan:
    """Parse a ``REPRO_CHAOS`` value into a plan.

    Format: ``site[,site...][:key=value]...`` with keys ``seed`` (int),
    ``max_fires`` (int or ``inf``), ``p`` (float probability) and
    ``kernels`` (``|``-separated names). Example::

        REPRO_CHAOS="rule_raise,exec_fail:seed=3:max_fires=1:kernels=rmsnorm|adamw"
    """
    parts = [p for p in spec.split(":") if p]
    if not parts:
        raise ValueError(f"empty {ENV_VAR} spec")
    sites = tuple(s.strip() for s in parts[0].split(",") if s.strip())
    kw: Dict[str, Any] = {}
    for opt in parts[1:]:
        if "=" not in opt:
            raise ValueError(f"bad {ENV_VAR} option {opt!r} "
                             f"(expected key=value)")
        k, v = opt.split("=", 1)
        if k == "seed":
            kw["seed"] = int(v)
        elif k == "max_fires":
            kw["max_fires"] = None if v in ("inf", "none") else int(v)
        elif k == "p":
            kw["probability"] = float(v)
        elif k == "kernels":
            kw["kernels"] = tuple(n for n in v.split("|") if n)
        else:
            raise ValueError(f"unknown {ENV_VAR} option {k!r}")
    return FaultPlan(sites=sites, **kw)


@contextmanager
def plan_scope(plan):
    """Temporarily install ``plan`` (a FaultPlan, a spec string, or
    None for a no-op scope); restores the previous plan on exit."""
    if plan is None:
        yield
        return
    if isinstance(plan, str):
        plan = plan_from_env(plan)
    global _PLAN
    with _LOCK:
        prev = _PLAN
    install_plan(plan)
    try:
        yield
    finally:
        install_plan(prev)


@contextmanager
def kernel_scope(name: Optional[str]):
    """Thread-local kernel context for the plan's ``kernels`` filter."""
    prev = getattr(_TLS, "kernel", None)
    _TLS.kernel = name
    try:
        yield
    finally:
        _TLS.kernel = prev


def current_kernel() -> Optional[str]:
    return getattr(_TLS, "kernel", None)


def chaos_point(site: str, kernel: Optional[str] = None) -> bool:
    """True iff this occurrence of ``site`` should fault. Near-free
    when no plan is active (one global read + None check)."""
    plan = _PLAN
    if plan is None:
        plan = _env_plan()
        if plan is None:
            return False
    if site not in plan.sites:
        return False
    if plan.kernels is not None:
        k = kernel if kernel is not None else current_kernel()
        if k not in plan.kernels:
            return False
    with _LOCK:
        if plan.max_fires is not None and \
                _FIRES.get(site, 0) >= plan.max_fires:
            return False
        occ = _OCCURRENCES.get(site, 0)
        _OCCURRENCES[site] = occ + 1
        if plan.probability < 1.0 and \
                _u01(plan.seed, site, occ) >= plan.probability:
            return False
        _FIRES[site] = _FIRES.get(site, 0) + 1
        k = kernel if kernel is not None else current_kernel()
    _tel().record_chaos(site, k)
    return True


def maybe_raise(site: str, kernel: Optional[str] = None,
                detail: str = ""):
    """Raise :class:`InjectedFault` when the plan fires ``site``."""
    if chaos_point(site, kernel):
        raise InjectedFault(site, detail)


def maybe_raise_os(site: str, errno_code: int, detail: str):
    """Raise a *real* ``OSError`` (tagged with ``.chaos_site``) when the
    plan fires — cache sites use this so the store's production OSError
    handlers are what gets exercised, not a special-cased chaos type."""
    if chaos_point(site):
        err = OSError(errno_code, f"injected: {detail}")
        err.chaos_site = site  # type: ignore[attr-defined]
        raise err


def fire_counts() -> Dict[str, int]:
    with _LOCK:
        return dict(_FIRES)


class ScheduledFaults:
    """Seeded one-shot keyed fault schedule (the registry behind
    ``ft.FailureInjector``): each armed key fires exactly once, and
    every fire is recorded in the shared chaos telemetry stream."""

    def __init__(self, site: str, schedule: Optional[Dict[Any, Any]] = None):
        if site not in FAULT_SITES:
            raise ValueError(f"unknown fault site {site!r}")
        self.site = site
        self._armed: Dict[Any, Any] = dict(schedule or {})
        self.fired: List[Any] = []
        self._lock = threading.Lock()

    def arm(self, key: Any, payload: Any = True):
        with self._lock:
            self._armed[key] = payload

    def check(self, key: Any) -> Optional[Any]:
        """The payload armed for ``key`` (once; None afterwards)."""
        with self._lock:
            if key not in self._armed or key in self.fired:
                return None
            self.fired.append(key)
            payload = self._armed[key]
        _tel().record_chaos(self.site, str(key))
        return payload
