"""Fault tolerance, elastic scaling, straggler mitigation.

Design (1000+-node posture, simulated faithfully on one process):

* **Failure detection** — every step ends with a heartbeat check. In a
  real deployment this is the JAX distributed runtime noticing a missing
  host; here a :class:`FailureInjector` raises on scheduled steps, which
  exercises the identical recovery path.
* **Checkpoint/restart** — :class:`repro.checkpoint.Checkpointer` commits
  atomically every ``ckpt_every`` steps; recovery restores the latest
  committed step and *replays data deterministically* from the step
  counter (the pipeline is (seed, step)-addressable, so no data state is
  checkpointed).
* **Elastic scaling** — on host loss the trainer shrinks the data axis
  (e.g. 16→8 shards), reshards the same checkpoint onto the smaller
  topology (restore is host-count agnostic), rebuilds the jitted step for
  the new mesh, and continues with the same global batch (more per-host
  rows) or a proportionally smaller one.
* **Straggler mitigation** — per-step deadline tracking with an EWMA of
  step time; a step exceeding ``straggler_factor ×`` the EWMA is logged
  and counted; after ``straggler_patience`` consecutive slow steps the
  trainer treats the host set as degraded and triggers the elastic path
  (in simulation: records the decision). Synchronous SGD makes "skip the
  slow host" equivalent to elastic re-sharding, which is what we do.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional

import jax
import numpy as np

from . import chaos


class FailureEvent(RuntimeError):
    def __init__(self, step: int, kind: str, lost_hosts: int = 1):
        super().__init__(f"simulated {kind} at step {step}")
        self.step = step
        self.kind = kind
        self.lost_hosts = lost_hosts


class FailureInjector:
    """Deterministic fault schedule: {step: (kind, lost_hosts)}.

    Since PR 10 this is a thin front end over the shared chaos
    registry (:class:`repro.runtime.chaos.ScheduledFaults`, site
    ``train_host_loss``): every fire lands in the same telemetry
    stream as the saturator chaos sites, and an active
    :class:`~repro.runtime.chaos.FaultPlan` naming ``train_host_loss``
    can inject host loss on top of the step schedule."""

    def __init__(self, schedule: Optional[Dict[int, Any]] = None):
        self._reg = chaos.ScheduledFaults("train_host_loss", schedule)

    @property
    def schedule(self) -> Dict[int, Any]:
        return self._reg._armed

    @property
    def fired(self) -> List[int]:
        return self._reg.fired

    def check(self, step: int):
        ev = self._reg.check(step)
        if ev is not None:
            kind, lost = ev if isinstance(ev, tuple) else (ev, 1)
            raise FailureEvent(step, kind, lost)
        if chaos.chaos_point("train_host_loss", kernel=""):
            raise FailureEvent(step, "chaos_host_loss", 1)


@dataclasses.dataclass
class StragglerPolicy:
    factor: float = 3.0          # slow if step_time > factor × EWMA
    patience: int = 3            # consecutive slow steps before action
    ewma: float = 0.1


@dataclasses.dataclass
class TrainLoopConfig:
    total_steps: int
    ckpt_every: int = 50
    ckpt_dir: str = "/tmp/repro_ckpt"
    keep: int = 3
    min_shards: int = 1
    straggler: StragglerPolicy = dataclasses.field(
        default_factory=StragglerPolicy)
    # Simulate the full host-process restart on recovery: drop every
    # in-process tile op (get_tile_op.cache_clear) so the rebuilt step
    # re-saturates — exactly what a replacement host does. The
    # persistent saturation cache + telemetry settings survive because
    # _recover re-applies the snapshot taken at __init__.
    simulate_host_restart: bool = False


class ElasticTrainer:
    """Synchronous data-parallel training loop with recovery.

    ``build_step(num_shards)`` returns (step_fn, pipeline) for the current
    topology — rebuilt after elastic events. The loop owns (params,
    opt_state) as host arrays in simulation.
    """

    def __init__(self, cfg: TrainLoopConfig, build_step: Callable,
                 params, opt_state, *, num_shards: int,
                 injector: Optional[FailureInjector] = None,
                 checkpointer=None):
        from repro.checkpoint import Checkpointer
        from repro.kernels import ops as _ops
        self.cfg = cfg
        self.build_step = build_step
        self.params = params
        self.opt_state = opt_state
        self.num_shards = num_shards
        self.injector = injector or FailureInjector()
        self.ckpt = checkpointer or Checkpointer(cfg.ckpt_dir, keep=cfg.keep)
        # Snapshot the process-global saturation settings so recovery can
        # restore them: a simulated host loss must come back with the
        # same persistent cache + verify level the run started with
        # (previously a restart fell back to cold, uncached builds).
        self._sat_cache = _ops.current_saturation_cache()
        self._sat_verify = _ops.current_saturation_verify()
        self.log: List[Dict[str, Any]] = []
        self.losses: List[float] = []
        self.step = 0
        self._ewma_time: Optional[float] = None
        self._slow_streak = 0
        self.recoveries = 0
        self.elastic_events: List[Dict[str, Any]] = []

    # -- main loop -----------------------------------------------------------------
    def run(self) -> Dict[str, Any]:
        step_fn, pipeline = self.build_step(self.num_shards)
        while self.step < self.cfg.total_steps:
            try:
                t0 = time.perf_counter()
                self.injector.check(self.step)
                batch = pipeline.batch_at(self.step)
                self.params, self.opt_state, loss = step_fn(
                    self.params, self.opt_state, batch)
                dt = time.perf_counter() - t0
                self._track_straggler(dt)
                self.losses.append(float(loss))
                if (self.step + 1) % self.cfg.ckpt_every == 0:
                    self._checkpoint()
                self.step += 1
            except FailureEvent as ev:
                step_fn, pipeline = self._recover(ev)
        self.ckpt.wait()
        self._checkpoint(sync=True)
        return {"losses": self.losses, "recoveries": self.recoveries,
                "elastic_events": self.elastic_events,
                "final_step": self.step,
                "straggler_flags": [e for e in self.log
                                    if e.get("straggler")]}

    # -- recovery -------------------------------------------------------------------
    def _recover(self, ev: FailureEvent):
        from repro.core.telemetry import telemetry
        from repro.kernels import ops as _ops
        from repro.kernels.tile_programs import get_tile_op
        self.recoveries += 1
        new_shards = max(self.num_shards - ev.lost_hosts,
                         self.cfg.min_shards)
        self.elastic_events.append(
            {"step": ev.step, "kind": ev.kind,
             "shards": (self.num_shards, new_shards)})
        self.num_shards = new_shards
        if self.cfg.simulate_host_restart:
            get_tile_op.cache_clear()
        # Re-apply the saturation settings snapshotted at __init__: the
        # rebuilt step must replay from the same persistent cache (warm
        # restart) and keep the same verification level, even if the
        # simulated replacement host started from process defaults.
        _ops.set_saturation_cache(self._sat_cache)
        _ops.set_saturation_verify(self._sat_verify)
        telemetry().record_recovery(ev.step, ev.kind, shards=new_shards)
        # restore the last committed state; data replays deterministically
        self.ckpt.wait()
        restored_step = self.ckpt.latest_step()
        if restored_step is not None:
            (self.params, self.opt_state), extra = self.ckpt.restore(
                (self.params, self.opt_state))
            self.step = int(extra.get("step", restored_step))
            # drop loss history past the restore point (recomputed)
            self.losses = self.losses[:self.step]
        else:
            self.step = 0
            self.losses = []
        return self.build_step(self.num_shards)

    def _checkpoint(self, sync: bool = False):
        self.ckpt.save(self.step + 1, (self.params, self.opt_state),
                       extra={"step": self.step + 1},
                       async_=not sync)

    # -- stragglers ------------------------------------------------------------------
    def _track_straggler(self, dt: float):
        pol = self.cfg.straggler
        if self._ewma_time is None:
            self._ewma_time = dt
            return
        slow = dt > pol.factor * self._ewma_time
        self.log.append({"step": self.step, "dt": dt, "straggler": slow})
        if slow:
            self._slow_streak += 1
            if self._slow_streak >= pol.patience:
                self.elastic_events.append(
                    {"step": self.step, "kind": "straggler_degrade",
                     "shards": (self.num_shards, self.num_shards)})
                self._slow_streak = 0
        else:
            self._slow_streak = 0
            self._ewma_time = (1 - pol.ewma) * self._ewma_time \
                + pol.ewma * dt
