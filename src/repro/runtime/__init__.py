"""Runtime robustness: elastic fault tolerance (``ft``), saturation
guards + the degradation ladder (``guard``), and deterministic fault
injection (``chaos``).

Lazy re-exports (PEP 562): ``guard``/``chaos`` are imported by deep
core modules (egraph/beam/schedule/rules) at module scope, so this
package ``__init__`` must not eagerly pull ``ft`` (which imports jax)
or anything from ``repro.core`` — attribute access resolves the owning
submodule on first use instead.
"""
from __future__ import annotations

_FT_NAMES = ("ElasticTrainer", "FailureEvent", "FailureInjector",
             "StragglerPolicy", "TrainLoopConfig")
_GUARD_NAMES = ("BudgetExceeded", "CircuitBreaker", "GuardConfig",
                "LADDER_LEVELS", "SaturationGuard", "breaker_for",
                "breakers_snapshot", "current_guard", "guard_tick",
                "reset_breakers", "run_ladder")
_CHAOS_NAMES = ("FAULT_SITES", "FaultPlan", "InjectedFault",
                "ScheduledFaults", "active_plan", "chaos_point",
                "clear_plan", "install_plan", "plan_from_env",
                "plan_scope")

__all__ = list(_FT_NAMES + _GUARD_NAMES + _CHAOS_NAMES)


def __getattr__(name: str):
    if name in _FT_NAMES:
        from . import ft as mod
    elif name in _GUARD_NAMES:
        from . import guard as mod  # type: ignore[no-redef]
    elif name in _CHAOS_NAMES:
        from . import chaos as mod  # type: ignore[no-redef]
    else:
        raise AttributeError(f"module {__name__!r} has no attribute "
                             f"{name!r}")
    return getattr(mod, name)
