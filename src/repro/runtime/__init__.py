from .ft import (ElasticTrainer, FailureEvent, FailureInjector,
                 StragglerPolicy, TrainLoopConfig)

__all__ = ["ElasticTrainer", "FailureEvent", "FailureInjector",
           "StragglerPolicy", "TrainLoopConfig"]
