"""Guarded saturation runtime: budgets, degradation ladder, breakers.

Equality saturation is non-destructive — stopping early or falling
back is always sound (Tate et al.) — so the robustness contract here
is a *guaranteed degradation ladder*, not retry-until-success:

    hit -> warm -> cold -> cheap -> ref

``hit``/``warm``/``cold`` are the persistent-cache outcomes of the full
configuration; ``cheap`` is a minimal deterministic search (beam width
1, legacy bulk emission with no schedule search, verify off, cache
off); ``ref`` is the reference interpreter from ``core/reference.py``
(and, at the kernels layer, the named oracles in ``kernels/ref.py``).
``repro.core.pipeline.saturate_program`` walks the ladder; nothing
inside it may raise to ``launch/serve.py`` / ``launch/train.py``.

Three guard mechanisms, all reported through ``core/telemetry.py``:

* :class:`SaturationGuard` — per-attempt hard ceilings. The primary
  limit is a *deterministic* eval-budget counter (``guard_tick`` calls
  from the saturation loop, beam expansion, hill climb, and schedule
  search); the wall-clock deadline and the e-graph node/class ceilings
  are safety nets only, so fault-free runs never depend on timing.
* :func:`run_ladder` — runs attempts top to bottom, converting any
  exception into a recorded degradation; only the floor failing
  re-raises (there is nothing left to fall to).
* :class:`CircuitBreaker` — per (kernel, config) key: after K
  consecutive failures of the primary attempt, skip straight to the
  last level that worked for a cool-down of N calls, then allow one
  half-open trial.

No top-level repro imports (telemetry is resolved lazily), so core
modules can import ``guard_tick`` at module scope without cycles.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from contextlib import contextmanager
from typing import Any, Callable, Dict, List, Optional, Tuple

from . import chaos

LADDER_LEVELS = ("hit", "warm", "cold", "cheap", "ref")


def _tel():
    from repro.core.telemetry import telemetry
    return telemetry()


class BudgetExceeded(RuntimeError):
    """A guard ceiling tripped. ``trigger`` names which one:
    ``eval_budget`` | ``deadline`` | ``node_ceiling`` | ``class_ceiling``
    | ``egraph_budget`` (chaos-injected exhaustion)."""

    def __init__(self, trigger: str, detail: str = ""):
        super().__init__(f"guard budget exceeded: {trigger}"
                         + (f" ({detail})" if detail else ""))
        self.trigger = trigger


@dataclasses.dataclass(frozen=True)
class GuardConfig:
    """Ceilings + ladder/breaker policy for one saturate call.

    ``eval_budget`` counts deterministic guard ticks (saturation
    iterations, beam expansions, hill-climb evals, schedule moves) and
    is the primary limit — generously above any sane build (a default
    full build spends well under 200k ticks). ``deadline_s`` and the
    e-graph ceilings are safety nets for runaway stages the tick
    counters cannot see. None of these fields enter the cache
    fingerprint (``repro.cache.keys`` lists its components explicitly),
    so tightening a budget never churns cache keys.

    ``chaos`` optionally carries a :class:`repro.runtime.chaos`
    plan-spec string scoped to the call (the config-level twin of the
    ``REPRO_CHAOS`` environment variable)."""
    eval_budget: int = 2_000_000
    deadline_s: float = 120.0
    node_ceiling: int = 200_000
    class_ceiling: int = 200_000
    ladder: bool = True
    breaker_threshold: int = 3
    breaker_cooldown: int = 8
    chaos: Optional[str] = None


_TLS = threading.local()


class SaturationGuard:
    """Hard ceilings for one ladder attempt; activated thread-locally
    so deep stages (egraph/beam/schedule) report via :func:`guard_tick`
    without threading a handle through every signature."""

    __slots__ = ("kernel", "cfg", "ticks", "stage", "_deadline")

    def __init__(self, kernel: str, cfg: Optional[GuardConfig] = None):
        self.kernel = kernel
        self.cfg = cfg or GuardConfig()
        self.ticks = 0
        self.stage = "init"
        self._deadline: Optional[float] = None

    def tick(self, stage: str, n: int = 1,
             nodes: Optional[int] = None,
             classes: Optional[int] = None):
        self.stage = stage
        cfg = self.cfg
        self.ticks += n
        if self.ticks > cfg.eval_budget:
            raise BudgetExceeded(
                "eval_budget", f"{self.ticks} ticks at {stage}")
        if nodes is not None and nodes > cfg.node_ceiling:
            raise BudgetExceeded(
                "node_ceiling", f"{nodes} e-nodes at {stage}")
        if classes is not None and classes > cfg.class_ceiling:
            raise BudgetExceeded(
                "class_ceiling", f"{classes} e-classes at {stage}")
        # wall clock is a safety net only — sampled every 1024 ticks so
        # the hot loops stay free of syscalls
        if self._deadline is not None and (self.ticks & 0x3FF) == 0 \
                and time.monotonic() > self._deadline:
            raise BudgetExceeded("deadline", f"at {stage}")

    @contextmanager
    def activate(self):
        prev = getattr(_TLS, "guard", None)
        _TLS.guard = self
        self._deadline = time.monotonic() + self.cfg.deadline_s
        try:
            with chaos.kernel_scope(self.kernel):
                yield self
        finally:
            _TLS.guard = prev


def current_guard() -> Optional[SaturationGuard]:
    return getattr(_TLS, "guard", None)


def guard_tick(stage: str, n: int = 1, nodes: Optional[int] = None,
               classes: Optional[int] = None):
    """Report progress to the ambient guard (no-op when none active —
    the fast path is one thread-local read)."""
    g = getattr(_TLS, "guard", None)
    if g is not None:
        g.tick(stage, n, nodes=nodes, classes=classes)


def classify_failure(exc: BaseException, stage: str) -> str:
    """Stable trigger label for telemetry: budget trips and injected
    faults keep their own names; anything else is ``stage:ExcType``."""
    if isinstance(exc, BudgetExceeded):
        return f"budget:{exc.trigger}"
    if isinstance(exc, chaos.InjectedFault):
        return f"chaos:{exc.site}"
    site = getattr(exc, "chaos_site", None)
    if site is not None:
        return f"chaos:{site}"
    return f"{stage}:{type(exc).__name__}"


class CircuitBreaker:
    """closed -> (K consecutive primary failures) -> open -> (cool-down
    of N admitted calls, skipping straight to the recorded fallback
    level) -> half-open (one trial) -> closed on success / re-open on
    failure. Cool-down is counted in calls, not seconds — deterministic
    under test and load-proportional in production."""

    def __init__(self, key: Any, threshold: int = 3, cooldown: int = 8):
        self.key = key
        self.threshold = max(1, threshold)
        self.cooldown = max(1, cooldown)
        self.state = "closed"
        self.failures = 0          # consecutive primary failures
        self._cooldown_left = 0
        self.fallback_level = "cheap"
        self._lock = threading.Lock()

    def admit(self) -> Optional[str]:
        """None = try the full ladder; a level name = skip straight to
        that rung (the breaker is open / another half-open trial is in
        flight)."""
        with self._lock:
            if self.state == "closed":
                return None
            if self.state == "open":
                self._cooldown_left -= 1
                if self._cooldown_left <= 0:
                    self.state = "half_open"
                    _tel().record_breaker(self.key, "half_open")
                    return None    # the one trial passes through
            return self.fallback_level

    def record_success(self):
        with self._lock:
            self.failures = 0
            if self.state != "closed":
                self.state = "closed"
                _tel().record_breaker(self.key, "close")

    def record_failure(self, fallback_level: Optional[str] = None):
        with self._lock:
            self.failures += 1
            if fallback_level is not None:
                self.fallback_level = fallback_level
            if self.state == "half_open" or self.failures >= self.threshold:
                if self.state != "open":
                    _tel().record_breaker(self.key, "open")
                self.state = "open"
                self._cooldown_left = self.cooldown


_BREAKERS: Dict[Any, CircuitBreaker] = {}
_BREAKERS_LOCK = threading.Lock()


def breaker_for(key: Any, threshold: int = 3,
                cooldown: int = 8) -> CircuitBreaker:
    """The process-wide breaker for ``key`` (created on first use; the
    policy of the first caller wins for the key's lifetime)."""
    with _BREAKERS_LOCK:
        br = _BREAKERS.get(key)
        if br is None:
            br = _BREAKERS[key] = CircuitBreaker(
                key, threshold=threshold, cooldown=cooldown)
        return br


def reset_breakers():
    with _BREAKERS_LOCK:
        _BREAKERS.clear()


def breakers_snapshot() -> Dict[str, Any]:
    with _BREAKERS_LOCK:
        states: Dict[str, int] = {}
        for br in _BREAKERS.values():
            states[br.state] = states.get(br.state, 0) + 1
        return {"total": len(_BREAKERS), "states": states}


def run_ladder(kernel: str,
               attempts: List[Tuple[str, Callable[[], Any]]],
               *, cfg: Optional[GuardConfig] = None,
               breaker: Optional[CircuitBreaker] = None
               ) -> Tuple[str, Any]:
    """Run ``attempts`` (ordered ``(level, thunk)`` rungs) under a fresh
    :class:`SaturationGuard` each, degrading on any exception. Returns
    ``(level, result)`` of the first rung that succeeds; only the floor
    failing re-raises. The breaker counts *primary* attempts: a skip
    drops straight to its recorded fallback rung."""
    cfg = cfg or GuardConfig()
    start = 0
    if breaker is not None:
        skip_to = breaker.admit()
        if skip_to is not None:
            _tel().record_breaker(kernel, "skip")
            start = next((i for i, (lv, _) in enumerate(attempts)
                          if lv == skip_to), len(attempts) - 1)
    first_trigger: Optional[str] = None
    last_err: Optional[BaseException] = None
    for i in range(start, len(attempts)):
        level, thunk = attempts[i]
        g = SaturationGuard(kernel, cfg)
        try:
            with g.activate():
                result = thunk()
        except BaseException as e:  # ladder contract: degrade on anything
            if isinstance(e, (KeyboardInterrupt, SystemExit)):
                raise
            trigger = classify_failure(e, g.stage)
            if first_trigger is None:
                first_trigger = trigger
            _tel().record_guard_failure(kernel, level, trigger)
            last_err = e
            continue
        if breaker is not None and start == 0:
            if i == 0:
                breaker.record_success()
            else:
                breaker.record_failure(fallback_level=level)
        if i > 0 or start > 0:
            _tel().record_degradation(
                kernel, level, first_trigger or "breaker_skip")
        return level, result
    if breaker is not None and start == 0:
        breaker.record_failure(fallback_level=attempts[-1][0])
    assert last_err is not None
    raise last_err
